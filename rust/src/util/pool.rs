//! Shared scoped thread pool — the one threading story for every parallel
//! hot path in the crate (mesh forward/feedback/σ-gradient, batch PTC
//! realization, GEMM row-banding, and the per-block ZO sweeps of IC/PM).
//!
//! Design (std-only, no rayon):
//!
//! * A fixed set of persistent workers is spawned once; parallel regions
//!   inject one job at a time (a chunk-indexed closure) and the submitting
//!   thread participates in draining it, so `threads == 1` never parks.
//! * Work distribution is an atomic claim counter over chunk indices —
//!   self-balancing without per-chunk channels or allocation.
//! * Job lifetime is tied to the submitting call: `parallel_for` does not
//!   return until every chunk has executed, which is what makes handing the
//!   workers a non-`'static` closure sound (the `Arc<Job>` keeps the
//!   bookkeeping alive for late-waking workers, and a late waker can never
//!   claim a chunk of a finished job because the claim counter is already
//!   exhausted).
//! * Nested parallel regions run inline on the calling thread (a
//!   thread-local re-entrancy flag), so `matmul` inside a parallel mesh
//!   strip degrades to the serial kernel instead of deadlocking.
//!
//! Pool size: `L2IGHT_THREADS` env var if set (≥1), else
//! `std::thread::available_parallelism()`. `threads == 1` (or tiny work —
//! see [`ThreadPool::parallel_for_sized`]) bypasses the pool entirely, which
//! is why serial results are bit-identical to the parallel ones: every
//! chunk computes the same values in the same order regardless of which
//! thread claims it.

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Below this many "flop-equivalents" of total work, `parallel_for_sized`
/// runs inline — waking the pool costs more than it saves. This is the
/// compiled-in default; the live threshold is [`par_min_work`], which the
/// autotuner profile (`linalg::tune`) may override per host.
pub const PAR_MIN_WORK: usize = 32_768;

/// Live inline-work threshold. Process-global (not per-pool) so every gate
/// in the crate sees one value: path selection — serial vs pooled — is then
/// a pure function of the problem size, and since both paths are bitwise
/// identical by construction, tuning this knob can never change numerics.
static PAR_MIN_WORK_RT: AtomicUsize = AtomicUsize::new(PAR_MIN_WORK);

/// The active inline-work threshold (default [`PAR_MIN_WORK`], possibly
/// overridden by the autotuner profile via [`set_par_min_work`]).
pub fn par_min_work() -> usize {
    PAR_MIN_WORK_RT.load(Ordering::Relaxed)
}

/// Override the inline-work threshold (autotuner profile load). Clamped to
/// ≥ 1; call before the hot paths start for a consistent process-wide view.
pub fn set_par_min_work(v: usize) {
    PAR_MIN_WORK_RT.store(v.max(1), Ordering::Relaxed);
}

thread_local! {
    /// True while this thread is a pool worker or is inside a parallel
    /// region it submitted — nested regions then run inline.
    static IN_PARALLEL: Cell<bool> = Cell::new(false);
}

/// Type-erased pointer to the job closure. Only dereferenced while the
/// submitting stack frame is alive (see module docs).
#[derive(Clone, Copy)]
struct FnPtr(*const (dyn Fn(usize) + Sync));
unsafe impl Send for FnPtr {}
unsafe impl Sync for FnPtr {}

/// One parallel region's bookkeeping, shared between submitter and workers.
struct Job {
    func: FnPtr,
    chunks: usize,
    /// Next chunk index to claim.
    next: AtomicUsize,
    /// Chunks claimed-and-finished accounting: counts down to 0.
    pending: AtomicUsize,
    /// Set when any chunk panicked (the panic is re-raised by the submitter).
    panicked: AtomicBool,
}

struct PoolState {
    job: Option<Arc<Job>>,
    /// Bumped once per submitted job so workers can tell new work from
    /// spurious wakeups.
    epoch: u64,
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    /// Workers wait here for new jobs.
    work_cv: Condvar,
    /// Submitters wait here for job completion (and for the slot to free).
    done_cv: Condvar,
}

/// A fixed-size thread pool running one chunk-indexed job at a time.
pub struct ThreadPool {
    shared: Arc<Shared>,
    threads: usize,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Pool with `threads` total lanes of parallelism (the submitting thread
    /// counts as one, so this spawns `threads - 1` workers).
    pub fn new(threads: usize) -> ThreadPool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState { job: None, epoch: 0, shutdown: false }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let mut handles = Vec::new();
        for i in 0..threads - 1 {
            let sh = shared.clone();
            let h = std::thread::Builder::new()
                .name(format!("l2ight-pool-{i}"))
                .spawn(move || worker_loop(&sh))
                .expect("spawn pool worker");
            handles.push(h);
        }
        ThreadPool { shared, threads, handles }
    }

    /// Total lanes of parallelism (including the submitting thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(0..n)` across the pool. Blocks until every index has executed.
    /// Indices are claimed dynamically, one at a time; each index runs
    /// exactly once. Panics (after completing the region) if any task
    /// panicked.
    pub fn parallel_for<F: Fn(usize) + Sync>(&self, n: usize, f: F) {
        if n == 0 {
            return;
        }
        let serial = self.threads <= 1 || n == 1 || IN_PARALLEL.with(|c| c.get());
        if serial {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let obj: &(dyn Fn(usize) + Sync) = &f;
        let job = Arc::new(Job {
            func: FnPtr(obj as *const (dyn Fn(usize) + Sync)),
            chunks: n,
            next: AtomicUsize::new(0),
            pending: AtomicUsize::new(n),
            panicked: AtomicBool::new(false),
        });
        {
            let mut st = self.shared.state.lock().unwrap();
            // One job at a time: wait for the slot (another user thread may
            // be mid-region; pool workers never reach here).
            while st.job.is_some() {
                st = self.shared.done_cv.wait(st).unwrap();
            }
            st.job = Some(job.clone());
            st.epoch += 1;
            self.shared.work_cv.notify_all();
        }
        // The submitter drains chunks too, flagged so nested regions inline.
        IN_PARALLEL.with(|c| c.set(true));
        work_on(&self.shared, &job);
        IN_PARALLEL.with(|c| c.set(false));
        let mut st = self.shared.state.lock().unwrap();
        while job.pending.load(Ordering::Acquire) != 0 {
            st = self.shared.done_cv.wait(st).unwrap();
        }
        st.job = None;
        // Wake any queued submitter waiting for the slot.
        self.shared.done_cv.notify_all();
        drop(st);
        if job.panicked.load(Ordering::Relaxed) {
            panic!("l2ight thread pool: a parallel task panicked");
        }
    }

    /// `parallel_for` with a work-size gate: if the region's total work
    /// (in rough flop-equivalents) is below [`par_min_work`], run inline —
    /// tiny meshes should not pay pool wakeup latency.
    pub fn parallel_for_sized<F: Fn(usize) + Sync>(&self, n: usize, total_work: usize, f: F) {
        if total_work < par_min_work() || self.threads <= 1 {
            for i in 0..n {
                f(i);
            }
        } else {
            self.parallel_for(n, f);
        }
    }

    /// Map `f` over `0..n` in parallel, preserving index order in the output.
    pub fn parallel_map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let mut out: Vec<Option<T>> = Vec::with_capacity(n);
        out.resize_with(n, || None);
        let slots = SendPtr(out.as_mut_ptr());
        self.parallel_for(n, |i| {
            // Safety: each index writes exactly one distinct slot, and the
            // Vec outlives the region (parallel_for blocks to completion).
            let slot = unsafe { &mut *slots.0.add(i) };
            *slot = Some(f(i));
        });
        out.into_iter().map(|o| o.expect("parallel_map slot unfilled")).collect()
    }

    /// Map `f(index, &mut item)` over a mutable slice with at most
    /// `max_lanes` concurrent tasks, preserving index order in the output.
    /// Each lane owns a disjoint contiguous chunk, so `max_lanes` is an
    /// honest upper bound on concurrency even when the pool is wider —
    /// the per-block fan-out used by the IC/PM stages. `max_lanes <= 1`
    /// runs inline.
    pub fn parallel_map_chunked<T, R, F>(&self, items: &mut [T], max_lanes: usize, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, &mut T) -> R + Sync,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        let lanes = max_lanes.clamp(1, n);
        if lanes == 1 {
            return items.iter_mut().enumerate().map(|(i, t)| f(i, t)).collect();
        }
        let chunk = n.div_ceil(lanes);
        let base = SendPtr(items.as_mut_ptr());
        self.parallel_map(n.div_ceil(chunk), |t| {
            let lo = t * chunk;
            let hi = (lo + chunk).min(n);
            let mut out = Vec::with_capacity(hi - lo);
            for i in lo..hi {
                // Safety: lanes own disjoint contiguous index ranges.
                let item = unsafe { &mut *base.0.add(i) };
                out.push(f(i, item));
            }
            out
        })
        .into_iter()
        .flatten()
        .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    IN_PARALLEL.with(|c| c.set(true));
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    if let Some(j) = st.job.clone() {
                        break j;
                    }
                    // Epoch moved but the job is already cleared — re-wait.
                    continue;
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        work_on(shared, &job);
    }
}

/// Claim and execute chunks until the counter is exhausted.
fn work_on(shared: &Shared, job: &Job) {
    loop {
        let i = job.next.fetch_add(1, Ordering::Relaxed);
        if i >= job.chunks {
            return;
        }
        let f = unsafe { &*job.func.0 };
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i)));
        if r.is_err() {
            job.panicked.store(true, Ordering::Relaxed);
        }
        // Release pairs with the submitter's Acquire load: all writes made
        // by this chunk are visible once pending reads 0.
        if job.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
            let _guard = shared.state.lock().unwrap();
            shared.done_cv.notify_all();
        }
    }
}

/// The process-wide pool used by the hot paths. Sized once, on first use.
pub fn global() -> &'static ThreadPool {
    static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();
    GLOBAL.get_or_init(|| ThreadPool::new(default_threads()))
}

/// Pool size policy: `L2IGHT_THREADS` or `available_parallelism`.
/// `L2IGHT_THREADS=0` is honored as "fully serial" (same as 1); a value
/// that doesn't parse is loudly ignored rather than silently widening the
/// pool to the whole machine.
pub fn default_threads() -> usize {
    if let Ok(raw) = std::env::var("L2IGHT_THREADS") {
        match raw.trim().parse::<usize>() {
            Ok(0) => return 1,
            Ok(n) => return n,
            Err(_) => {
                crate::warn!("ignoring invalid L2IGHT_THREADS={raw:?} (not a number); using available parallelism");
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Raw-pointer courier for handing disjoint mutable regions to pool tasks.
/// The caller is responsible for index-disjointness; every hot-path use
/// writes region `i` from task `i` only. The `T: Send` bound keeps the
/// compiler's thread-safety check: workers materialize disjoint `&mut T`
/// from this, which is exactly a send of `T` to another thread.
#[derive(Clone, Copy)]
pub struct SendPtr<T>(pub *mut T);
unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

// ---------------------------------------------------------------------------
// Per-thread scratch arena
// ---------------------------------------------------------------------------

thread_local! {
    /// Small stack of reusable f32 buffers per thread (the "scratch arena").
    static SCRATCH: RefCell<Vec<Vec<f32>>> = RefCell::new(Vec::new());
}

/// A zeroed f32 scratch buffer borrowed from the per-thread arena; returned
/// on drop. Eliminates the per-call panel/workspace allocations in the mesh
/// hot paths (`Vec<Mat>` slicing) without threading buffers through APIs.
pub struct Scratch {
    buf: Vec<f32>,
}

impl Scratch {
    /// Take a zero-filled buffer of exactly `len` floats.
    pub fn take(len: usize) -> Scratch {
        let mut buf = SCRATCH
            .try_with(|s| s.borrow_mut().pop())
            .ok()
            .flatten()
            .unwrap_or_default();
        buf.clear();
        buf.resize(len, 0.0);
        Scratch { buf }
    }
}

impl Drop for Scratch {
    fn drop(&mut self) {
        let buf = std::mem::take(&mut self.buf);
        // Cap the arena so pathological sizes don't pin memory forever.
        let _ = SCRATCH.try_with(|s| {
            let mut v = s.borrow_mut();
            if v.len() < 8 {
                v.push(buf);
            }
        });
    }
}

impl std::ops::Deref for Scratch {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        &self.buf
    }
}

impl std::ops::DerefMut for Scratch {
    fn deref_mut(&mut self) -> &mut [f32] {
        &mut self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_every_index_exactly_once() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicUsize> = (0..257).map(|_| AtomicUsize::new(0)).collect();
        pool.parallel_for(hits.len(), |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = ThreadPool::new(1);
        let sum = AtomicU64::new(0);
        pool.parallel_for(100, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4950);
    }

    #[test]
    fn more_threads_than_items() {
        let pool = ThreadPool::new(8);
        let out = pool.parallel_map(3, |i| i * 10);
        assert_eq!(out, vec![0, 10, 20]);
    }

    #[test]
    fn empty_work_list_is_noop() {
        let pool = ThreadPool::new(4);
        pool.parallel_for(0, |_| panic!("must not run"));
        let out: Vec<usize> = pool.parallel_map(0, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.parallel_map(1000, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn nested_regions_run_inline() {
        let pool = ThreadPool::new(4);
        let total = AtomicU64::new(0);
        pool.parallel_for(8, |_| {
            // Nested call must not deadlock on the single job slot.
            pool.parallel_for(8, |j| {
                total.fetch_add(j as u64, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 8 * 28);
    }

    #[test]
    fn sequential_jobs_reuse_workers() {
        let pool = ThreadPool::new(3);
        for round in 0..50 {
            let sum = AtomicU64::new(0);
            pool.parallel_for(17, |i| {
                sum.fetch_add((i + round) as u64, Ordering::Relaxed);
            });
            assert_eq!(sum.load(Ordering::Relaxed), (136 + 17 * round) as u64);
        }
    }

    #[test]
    #[should_panic(expected = "a parallel task panicked")]
    fn task_panic_propagates() {
        let pool = ThreadPool::new(4);
        pool.parallel_for(8, |i| {
            if i == 3 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn sized_gate_runs_small_work_inline() {
        let pool = ThreadPool::new(4);
        let sum = AtomicU64::new(0);
        pool.parallel_for_sized(4, 16, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 6);
    }

    #[test]
    fn scratch_is_zeroed_and_reused() {
        {
            let mut s = Scratch::take(64);
            assert!(s.iter().all(|&v| v == 0.0));
            s[0] = 5.0;
        }
        let s2 = Scratch::take(32);
        assert_eq!(s2.len(), 32);
        assert!(s2.iter().all(|&v| v == 0.0), "recycled scratch must be re-zeroed");
    }

    #[test]
    fn default_threads_is_at_least_one() {
        assert!(default_threads() >= 1);
    }
}
