//! Seeded property-based testing helper (the vendored crate set has no
//! proptest). `check` runs a property over `n` generated cases; on failure it
//! reports the case index and the seed so the exact input can be replayed.
//! No shrinking — cases are generated smallest-first instead, which gives
//! most of shrinking's debuggability at a fraction of the machinery.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, seed: 0x5eed_cafe }
    }
}

/// Run `prop` for `cfg.cases` generated inputs. `gen` receives the RNG and a
/// size hint that grows from 1 to 100 across the run (so early cases are
/// small). `prop` returns `Err(msg)` to fail.
pub fn check<T, G, P>(name: &str, cfg: PropConfig, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng, usize) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let mut rng = Rng::with_stream(cfg.seed, case as u64 + 1);
        let size = 1 + (case * 100) / cfg.cases.max(1);
        let input = gen(&mut rng, size);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case}/{} (seed={:#x}, size={size}):\n  {msg}\n  input: {input:?}",
                cfg.cases, cfg.seed
            );
        }
    }
}

/// Shorthand with default config.
pub fn quickcheck<T, G, P>(name: &str, gen: G, prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng, usize) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    check(name, PropConfig::default(), gen, prop)
}

/// Assert two f32 slices are elementwise close.
pub fn assert_close(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol || x.is_nan() != y.is_nan() {
            return Err(format!("element {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

/// Max absolute difference between two slices.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b.iter()).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        quickcheck(
            "reverse twice is identity",
            |rng, size| (0..size).map(|_| rng.next_u32()).collect::<Vec<_>>(),
            |v| {
                let mut w = v.clone();
                w.reverse();
                w.reverse();
                if w == *v { Ok(()) } else { Err("mismatch".into()) }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_context() {
        quickcheck("always fails", |rng, _| rng.next_u32(), |_| Err("nope".into()));
    }

    #[test]
    fn close_checks() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-6, 1e-6).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-3, 1e-3).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1.0, 1.0).is_err());
        assert_eq!(max_abs_diff(&[1.0, 5.0], &[2.0, 3.0]), 2.0);
    }
}
