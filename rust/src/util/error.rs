//! Std-only error type with context chaining — an in-repo stand-in for the
//! `anyhow` surface the runtime/coordinator modules use (`anyhow!`, `bail!`,
//! `Context::{context, with_context}`, `Result`). The vendored crate set has
//! no anyhow, and tier-1 must build from a clean checkout with zero external
//! dependencies.
//!
//! Formatting mirrors anyhow: `{}` prints the outermost message, `{:#}`
//! prints the full chain outermost-first separated by `": "`.

use std::fmt;

/// A message-chain error. Frames are stored root-first; `context` pushes an
/// outer frame.
#[derive(Debug, Clone)]
pub struct Error {
    frames: Vec<String>,
}

impl Error {
    /// New error from a message.
    pub fn msg(m: impl Into<String>) -> Error {
        Error { frames: vec![m.into()] }
    }

    /// Wrap with an outer context frame.
    pub fn context(mut self, c: impl Into<String>) -> Error {
        self.frames.push(c.into());
        self
    }

    /// The root-cause message.
    pub fn root_cause(&self) -> &str {
        &self.frames[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: outermost-first chain, anyhow-style.
            for (i, frame) in self.frames.iter().rev().enumerate() {
                if i > 0 {
                    write!(f, ": ")?;
                }
                write!(f, "{frame}")?;
            }
            Ok(())
        } else {
            write!(f, "{}", self.frames.last().unwrap())
        }
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e.to_string())
    }
}

/// Crate-wide result alias (anyhow-compatible shape).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a fallible value (anyhow's `Context` trait surface).
pub trait Context<T> {
    fn context<C: Into<String>>(self, c: C) -> Result<T>;
    fn with_context<C: Into<String>, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: Into<String>>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: Into<String>, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Into<String>>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: Into<String>, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Early-return an [`Error`] from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

// Allow `use crate::util::error::{anyhow, bail}` alongside the type imports.
pub use crate::{anyhow, bail};

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("root {}", 42)
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(format!("{e}"), "root 42");
        assert_eq!(format!("{e:#}"), "root 42");
    }

    #[test]
    fn context_chains_outermost_first() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root 42");
        assert_eq!(e.root_cause(), "root 42");
    }

    #[test]
    fn with_context_from_std_error() {
        let r: std::result::Result<String, std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        let e = r.with_context(|| "reading config".to_string()).unwrap_err();
        assert!(format!("{e:#}").starts_with("reading config: "));
        assert!(format!("{e:#}").contains("gone"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
    }
}
