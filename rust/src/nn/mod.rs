//! Neural-network layer substrate with explicit backward passes.
//!
//! Activations flow in *feature-major* layout (`Act`: a [C, B·H·W] matrix
//! plus NCHW metadata) because the photonic mesh consumes column panels —
//! this is the same layout the im2col lowering produces, so the sampling
//! machinery (§3.4.2) can mask matrix columns directly.
//!
//! Every projection layer (Linear/Conv2d) is generic over a projection
//! engine (`engine::ProjEngine`): `Digital` (dense weights, full-space
//! autograd — used for software pretraining and as the noise-free baseline)
//! or `Photonic` (a `PtcMesh`; only Σ receives gradients — the restricted
//! subspace of §3.4).

pub mod act;
pub mod engine;
pub mod layers;
pub mod loss;
pub mod model;
pub mod models;

pub use act::Act;
pub use engine::{EngineKind, ProjEngine};
pub use layers::Layer;
pub use loss::{accuracy, softmax_cross_entropy};
pub use model::{forward_nodes, BackwardCtx, Model, Node, ParamKey};
pub use models::{build_model, ModelArch};
