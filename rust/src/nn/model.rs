//! Model graph: a sequence of nodes, where a node is either a plain layer or
//! a residual block (body + optional shortcut) — enough graph structure for
//! the paper's model zoo (MLP, CNN-S, CNN-L, VGG-8, ResNet-18).

use super::act::Act;
use super::engine::ProjEngine;
use super::layers::Layer;
use crate::optim::Optimizer;
use crate::sampling::{ColumnSampler, FeedbackMask, FeedbackSampler};
use crate::util::Rng;

/// Sampling context threaded through a backward pass (one per iteration).
#[derive(Clone, Debug)]
pub struct BackwardCtx {
    /// Feedback-matrix sampler (None = dense feedback).
    pub feedback: Option<FeedbackSampler>,
    /// Feature sampler (CS / SS / off).
    pub feature: ColumnSampler,
    pub rng: Rng,
}

impl BackwardCtx {
    /// Dense backward, no sampling.
    pub fn plain(rng: Rng) -> BackwardCtx {
        BackwardCtx { feedback: None, feature: ColumnSampler::OFF, rng }
    }

    /// Draw a feedback mask sized for `engine`'s block grid.
    pub fn draw_feedback(&mut self, engine: &ProjEngine) -> Option<FeedbackMask> {
        match self.feedback {
            None => None,
            Some(sampler) => {
                let (p, q, norms) = engine.block_norms();
                Some(sampler.draw(p, q, &norms, &mut self.rng))
            }
        }
    }
}

/// A node in the model graph.
#[derive(Clone, Debug)]
pub enum Node {
    Plain(Layer),
    /// out = body(x) + shortcut(x); empty shortcut = identity skip.
    Residual { body: Vec<Node>, shortcut: Vec<Node> },
}

/// Stable identifier of one parameter tensor (traversal order).
pub type ParamKey = usize;

/// A trainable model.
#[derive(Clone, Debug)]
pub struct Model {
    pub nodes: Vec<Node>,
    pub name: String,
}

impl Model {
    pub fn new(name: &str, nodes: Vec<Node>) -> Model {
        Model { nodes, name: name.to_string() }
    }

    pub fn forward(&mut self, x: &Act, train: bool) -> Act {
        forward_nodes(&mut self.nodes, x, train)
    }

    pub fn backward(&mut self, dy: &Act, ctx: &mut BackwardCtx) -> Act {
        backward_nodes(&mut self.nodes, dy, ctx)
    }

    /// Visit every layer depth-first (stable order).
    pub fn for_each_layer<F: FnMut(&mut Layer)>(&mut self, mut f: F) {
        fn rec<F: FnMut(&mut Layer)>(nodes: &mut [Node], f: &mut F) {
            for n in nodes {
                match n {
                    Node::Plain(l) => f(l),
                    Node::Residual { body, shortcut } => {
                        rec(body, f);
                        rec(shortcut, f);
                    }
                }
            }
        }
        rec(&mut self.nodes, &mut f);
    }

    /// Zero all gradient accumulators.
    pub fn zero_grad(&mut self) {
        self.for_each_layer(|l| {
            if let Some(e) = l.engine_mut() {
                e.zero_grad();
            }
            match l {
                Layer::Linear(lin) => lin.grad_bias.fill(0.0),
                Layer::Conv2d(c) => c.grad_bias.fill(0.0),
                Layer::BatchNorm(bn) => {
                    bn.grad_gamma.fill(0.0);
                    bn.grad_beta.fill(0.0);
                }
                _ => {}
            }
        });
    }

    /// Apply one optimizer step to every trainable tensor. Weight decay is
    /// applied to projection weights/Σ only (not biases or BN affine).
    pub fn step(&mut self, opt: &mut dyn Optimizer) {
        let mut key: ParamKey = 0;
        self.for_each_layer(|l| {
            if let Some(e) = l.engine_mut() {
                match e {
                    ProjEngine::Digital { w, grad_w, .. } => {
                        opt.step(key, &mut w.data, &grad_w.data, true);
                    }
                    ProjEngine::Photonic { mesh, grad_sigma, .. } => {
                        let mut sigma = mesh.sigma_flat();
                        opt.step(key, &mut sigma, grad_sigma, true);
                        mesh.set_sigma_flat(&sigma);
                    }
                    ProjEngine::PhotonicSharded { mesh, grad_sigma, .. } => {
                        // Logical-order Σ: same param key layout as unsharded.
                        let mut sigma = mesh.sigma_flat();
                        opt.step(key, &mut sigma, grad_sigma, true);
                        mesh.set_sigma_flat(&sigma);
                    }
                }
                key += 1;
            }
            match l {
                Layer::Linear(lin) => {
                    opt.step(key, &mut lin.bias, &lin.grad_bias.clone(), false);
                    key += 1;
                }
                Layer::Conv2d(c) => {
                    opt.step(key, &mut c.bias, &c.grad_bias.clone(), false);
                    key += 1;
                }
                Layer::BatchNorm(bn) => {
                    opt.step(key, &mut bn.gamma, &bn.grad_gamma.clone(), false);
                    key += 1;
                    opt.step(key, &mut bn.beta, &bn.grad_beta.clone(), false);
                    key += 1;
                }
                _ => {}
            }
        });
    }

    /// (trainable parameter count, total parameter count). For photonic
    /// engines trainable = Σ values (the restricted subspace); total counts
    /// the full dense-equivalent weight (what the paper's "#Params" reports).
    pub fn param_counts(&mut self) -> (usize, usize) {
        let mut trainable = 0usize;
        let mut total = 0usize;
        self.for_each_layer(|l| {
            if let Some(e) = l.engine_mut() {
                match e {
                    ProjEngine::Digital { w, .. } => {
                        trainable += w.data.len();
                        total += w.data.len();
                    }
                    ProjEngine::Photonic { mesh, .. } => {
                        trainable += mesh.n_sigma();
                        total += mesh.rows * mesh.cols;
                    }
                    ProjEngine::PhotonicSharded { mesh, .. } => {
                        trainable += mesh.n_sigma();
                        total += mesh.rows * mesh.cols;
                    }
                }
            }
            match l {
                Layer::Linear(lin) => {
                    trainable += lin.bias.len();
                    total += lin.bias.len();
                }
                Layer::Conv2d(c) => {
                    trainable += c.bias.len();
                    total += c.bias.len();
                }
                Layer::BatchNorm(bn) => {
                    trainable += 2 * bn.gamma.len();
                    total += 2 * bn.gamma.len();
                }
                _ => {}
            }
        });
        (trainable, total)
    }

    /// Clear cached forward state in every layer.
    pub fn clear_caches(&mut self) {
        self.for_each_layer(|l| l.clear_cache());
    }

    /// Sum of hardware-op statistics over all photonic meshes.
    pub fn mesh_stats(&mut self) -> crate::photonics::mesh::MeshStats {
        let mut acc = crate::photonics::mesh::MeshStats::default();
        self.for_each_layer(|l| match l.engine_mut() {
            Some(ProjEngine::Photonic { mesh, .. }) => acc.add(&mesh.stats),
            Some(ProjEngine::PhotonicSharded { mesh, .. }) => acc.add(&mesh.stats()),
            _ => {}
        });
        acc
    }

    /// Reset hardware-op statistics.
    pub fn reset_mesh_stats(&mut self) {
        self.for_each_layer(|l| match l.engine_mut() {
            Some(ProjEngine::Photonic { mesh, .. }) => mesh.stats = Default::default(),
            Some(ProjEngine::PhotonicSharded { mesh, .. }) => mesh.reset_stats(),
            _ => {}
        });
    }
}

/// Run a node slice as a sub-network. Public because the serve replica
/// substitutes a packed-panel first layer (`Linear::forward_gathered`)
/// and then continues through the remainder of the graph with this.
pub fn forward_nodes(nodes: &mut [Node], x: &Act, train: bool) -> Act {
    let mut cur = x.clone();
    for n in nodes.iter_mut() {
        cur = match n {
            Node::Plain(l) => l.forward(&cur, train),
            Node::Residual { body, shortcut } => {
                let main = forward_nodes(body, &cur, train);
                let skip = if shortcut.is_empty() {
                    cur.clone()
                } else {
                    forward_nodes(shortcut, &cur, train)
                };
                assert_eq!(
                    (main.mat.rows, main.mat.cols),
                    (skip.mat.rows, skip.mat.cols),
                    "residual shape mismatch"
                );
                Act { mat: main.mat.add(&skip.mat), ..main }
            }
        };
    }
    cur
}

fn backward_nodes(nodes: &mut [Node], dy: &Act, ctx: &mut BackwardCtx) -> Act {
    let mut cur = dy.clone();
    for n in nodes.iter_mut().rev() {
        cur = match n {
            Node::Plain(l) => l.backward(&cur, ctx),
            Node::Residual { body, shortcut } => {
                let d_main = backward_nodes(body, &cur, ctx);
                let d_skip = if shortcut.is_empty() {
                    cur.clone()
                } else {
                    backward_nodes(shortcut, &cur, ctx)
                };
                Act { mat: d_main.mat.add(&d_skip.mat), ..d_main }
            }
        };
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::nn::engine::EngineKind;
    use crate::nn::layers::{Linear, Relu};
    use crate::nn::loss::softmax_cross_entropy;
    use crate::optim::Sgd;

    fn tiny_mlp(rng: &mut Rng) -> Model {
        Model::new(
            "tiny",
            vec![
                Node::Plain(Layer::Linear(Linear::new(ProjEngine::new(
                    EngineKind::Digital,
                    8,
                    4,
                    rng,
                )))),
                Node::Plain(Layer::Relu(Relu::new())),
                Node::Plain(Layer::Linear(Linear::new(ProjEngine::new(
                    EngineKind::Digital,
                    3,
                    8,
                    rng,
                )))),
            ],
        )
    }

    #[test]
    fn sgd_reduces_loss_on_toy_task() {
        let mut rng = Rng::new(1);
        let mut model = tiny_mlp(&mut rng);
        let x = Act::from_features(Mat::randn(4, 16, 1.0, &mut rng), 16);
        let labels: Vec<usize> = (0..16).map(|i| i % 3).collect();
        let mut opt = Sgd::new(0.5, 0.9, 0.0);
        let mut first = 0.0;
        let mut last = 0.0;
        for it in 0..60 {
            let logits = model.forward(&x, true);
            let (loss, dl) = softmax_cross_entropy(&logits.mat, &labels);
            if it == 0 {
                first = loss;
            }
            last = loss;
            model.zero_grad();
            let mut ctx = BackwardCtx::plain(Rng::new(it as u64));
            model.backward(&Act::from_features(dl, 16), &mut ctx);
            model.step(&mut opt);
        }
        assert!(last < first * 0.3, "loss did not drop: {first} -> {last}");
    }

    #[test]
    fn residual_identity_gradient_splits() {
        // Residual with empty body? Use body = [Relu] so shapes match; the
        // skip must add dy to the body gradient.
        let mut rng = Rng::new(2);
        let mut model = Model::new(
            "res",
            vec![Node::Residual {
                body: vec![Node::Plain(Layer::Relu(Relu::new()))],
                shortcut: vec![],
            }],
        );
        let x = Act::from_features(Mat::from_slice(2, 1, &[1.0, -1.0]), 1);
        let y = model.forward(&x, true);
        // y = relu(x) + x = [2, -1]
        assert_eq!(y.mat.data, vec![2.0, -1.0]);
        let dy = Act::from_features(Mat::from_slice(2, 1, &[1.0, 1.0]), 1);
        let mut ctx = BackwardCtx::plain(Rng::new(3));
        let dx = model.backward(&dy, &mut ctx);
        // d/dx (relu(x)+x) = mask + 1 = [2, 1]
        assert_eq!(dx.mat.data, vec![2.0, 1.0]);
        let _ = rng.next_u32();
    }

    #[test]
    fn param_counts_subspace_vs_full() {
        let mut rng = Rng::new(3);
        let mut m = Model::new(
            "p",
            vec![Node::Plain(Layer::Linear(Linear::new(ProjEngine::new(
                EngineKind::Photonic { k: 3, noise: crate::photonics::NoiseModel::IDEAL },
                9,
                9,
                &mut rng,
            ))))],
        );
        let (tr, total) = m.param_counts();
        // 3x3 grid of 3x3 blocks: sigma = 9 blocks * 3 = 27 (+9 bias), full = 81 (+9).
        assert_eq!(tr, 27 + 9);
        assert_eq!(total, 81 + 9);
    }
}
