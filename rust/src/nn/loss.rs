//! Softmax cross-entropy loss and classification accuracy over
//! feature-major logits [classes, batch].

use crate::linalg::Mat;

/// Numerically-stable softmax cross-entropy. Returns (mean loss, dlogits)
/// where dlogits already carries the 1/B factor.
pub fn softmax_cross_entropy(logits: &Mat, labels: &[usize]) -> (f32, Mat) {
    let (c, b) = (logits.rows, logits.cols);
    assert_eq!(labels.len(), b, "labels/batch mismatch");
    let mut dl = Mat::zeros(c, b);
    let mut loss = 0.0f64;
    let inv_b = 1.0 / b as f32;
    for col in 0..b {
        let mut maxv = f32::NEG_INFINITY;
        for r in 0..c {
            maxv = maxv.max(logits[(r, col)]);
        }
        let mut z = 0.0f32;
        for r in 0..c {
            z += (logits[(r, col)] - maxv).exp();
        }
        let logz = z.ln();
        let y = labels[col];
        assert!(y < c, "label {y} out of range {c}");
        loss += (logz - (logits[(y, col)] - maxv)) as f64;
        for r in 0..c {
            let p = (logits[(r, col)] - maxv).exp() / z;
            dl[(r, col)] = (p - if r == y { 1.0 } else { 0.0 }) * inv_b;
        }
    }
    ((loss / b as f64) as f32, dl)
}

/// Top-1 accuracy of logits against labels.
pub fn accuracy(logits: &Mat, labels: &[usize]) -> f32 {
    let (c, b) = (logits.rows, logits.cols);
    let mut correct = 0usize;
    for col in 0..b {
        let mut best = 0usize;
        let mut bestv = f32::NEG_INFINITY;
        for r in 0..c {
            if logits[(r, col)] > bestv {
                bestv = logits[(r, col)];
                best = r;
            }
        }
        if best == labels[col] {
            correct += 1;
        }
    }
    correct as f32 / b as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn perfect_prediction_low_loss() {
        let mut logits = Mat::zeros(3, 2);
        logits[(0, 0)] = 10.0;
        logits[(2, 1)] = 10.0;
        let (loss, _) = softmax_cross_entropy(&logits, &[0, 2]);
        assert!(loss < 1e-3, "loss {loss}");
        assert_eq!(accuracy(&logits, &[0, 2]), 1.0);
        assert_eq!(accuracy(&logits, &[1, 1]), 0.0);
    }

    #[test]
    fn uniform_logits_loss_is_log_c() {
        let logits = Mat::zeros(10, 4);
        let (loss, _) = softmax_cross_entropy(&logits, &[0, 3, 5, 9]);
        assert!((loss - (10.0f32).ln()).abs() < 1e-5);
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let mut rng = Rng::new(1);
        let logits = Mat::randn(5, 3, 1.0, &mut rng);
        let labels = vec![1usize, 4, 0];
        let (_, dl) = softmax_cross_entropy(&logits, &labels);
        let eps = 1e-3f32;
        for probe in [0usize, 7, 14] {
            let mut lp = logits.clone();
            lp.data[probe] += eps;
            let mut lm = logits.clone();
            lm.data[probe] -= eps;
            let (fp, _) = softmax_cross_entropy(&lp, &labels);
            let (fm, _) = softmax_cross_entropy(&lm, &labels);
            let fd = (fp - fm) / (2.0 * eps);
            assert!((fd - dl.data[probe]).abs() < 1e-3, "probe {probe}");
        }
    }

    #[test]
    fn gradient_sums_to_zero_per_column() {
        let mut rng = Rng::new(2);
        let logits = Mat::randn(7, 4, 2.0, &mut rng);
        let (_, dl) = softmax_cross_entropy(&logits, &[0, 1, 2, 3]);
        for col in 0..4 {
            let s: f32 = (0..7).map(|r| dl[(r, col)]).sum();
            assert!(s.abs() < 1e-6);
        }
    }

    #[test]
    fn stability_with_large_logits() {
        let mut logits = Mat::zeros(3, 1);
        logits[(0, 0)] = 1e4;
        logits[(1, 0)] = -1e4;
        let (loss, dl) = softmax_cross_entropy(&logits, &[0]);
        assert!(loss.is_finite());
        assert!(dl.data.iter().all(|v| v.is_finite()));
    }
}
