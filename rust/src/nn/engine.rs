//! Projection engines: the matrix-multiply workhorse behind Linear/Conv2d.
//!
//! * `Digital` — a dense f32 weight with full-space gradients. Used for
//!   software pretraining (the model that PM maps onto the chip) and for
//!   the noise-free reference curves in Fig. 1(b).
//! * `Photonic` — a `PtcMesh`. Forward runs through the realized (noisy)
//!   blocked mesh; backward produces the Σ subspace gradient via the Eq. 5
//!   reciprocity rule and the masked feedback product of §3.4.2. Full-space
//!   weight gradients simply do not exist here, matching the hardware.
//!
//! Both engines route every matrix product through the shared compute
//! engine (`linalg::gemm` tiled kernels + `util::pool` banding), so layer
//! forward/backward parallelize without any threading code here.

use crate::linalg::{gemm_packed_panels, matmul, matmul_a_bt, matmul_a_bt_acc, matmul_at_b, Mat};
use crate::photonics::{NoiseModel, PtcMesh, ShardPolicy, ShardedMesh};
use crate::sampling::feedback::FeedbackMask;
use crate::util::pool;
use crate::util::Rng;

/// How to instantiate projection engines when building a model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EngineKind {
    Digital,
    /// Photonic with block size k and a noise model.
    Photonic { k: usize, noise: NoiseModel },
    /// Photonic partitioned across several chiplet shards. Bitwise-identical
    /// to `Photonic` at every shard count (see `photonics::shard`); only the
    /// per-shard hardware accounting differs.
    PhotonicSharded { k: usize, noise: NoiseModel, shards: usize, policy: ShardPolicy },
}

/// A projection engine computing y = W·x with engine-specific training.
#[derive(Clone, Debug)]
pub enum ProjEngine {
    Digital {
        w: Mat,
        grad_w: Mat,
        /// Optional forward-weight keep-mask (SWAT-U baseline sparsifies the
        /// forward weights too); None = dense forward.
        fwd_mask: Option<Vec<bool>>,
    },
    Photonic {
        mesh: PtcMesh,
        grad_sigma: Vec<f32>,
        /// Optional forward block keep-mask [p][q] + scale (SWAT-U baseline
        /// shares one mask between forward and feedback).
        fwd_mask: Option<(Vec<bool>, f32)>,
    },
    /// Sharded photonic backing: same training semantics as `Photonic`
    /// (logical-order Σ subspace, logical [p][q] masks), executed across
    /// several independently owned mesh shards.
    PhotonicSharded {
        mesh: ShardedMesh,
        grad_sigma: Vec<f32>,
        /// Logical-grid forward block keep-mask [p][q] + scale.
        fwd_mask: Option<(Vec<bool>, f32)>,
    },
}

impl ProjEngine {
    /// Kaiming-uniform initialized engine for an `out`×`inp` projection.
    pub fn new(kind: EngineKind, out: usize, inp: usize, rng: &mut Rng) -> ProjEngine {
        let bound = (6.0 / inp as f32).sqrt();
        let w = Mat::rand_uniform(out, inp, -bound, bound, rng);
        match kind {
            EngineKind::Digital => ProjEngine::Digital {
                grad_w: Mat::zeros(out, inp),
                w,
                fwd_mask: None,
            },
            EngineKind::Photonic { k, noise } => {
                let mut mesh = PtcMesh::new(out, inp, k, noise, rng);
                // Subspace-from-scratch initialization: random unitaries are
                // whatever the fab + IC produced; Σ starts from the SVD of a
                // Kaiming init so training-from-scratch is well-scaled.
                mesh.program_from_dense(&w);
                ProjEngine::Photonic {
                    grad_sigma: vec![0.0; mesh.n_sigma()],
                    mesh,
                    fwd_mask: None,
                }
            }
            EngineKind::PhotonicSharded { k, noise, shards, policy } => {
                // Same RNG stream + same per-block programming as the
                // unsharded engine — device state is bit-identical to
                // `Photonic` at any shard count.
                let mut mesh = ShardedMesh::new(out, inp, k, noise, shards, policy, rng);
                mesh.program_from_dense(&w);
                ProjEngine::PhotonicSharded {
                    grad_sigma: vec![0.0; mesh.n_sigma()],
                    mesh,
                    fwd_mask: None,
                }
            }
        }
    }

    pub fn out_features(&self) -> usize {
        match self {
            ProjEngine::Digital { w, .. } => w.rows,
            ProjEngine::Photonic { mesh, .. } => mesh.rows,
            ProjEngine::PhotonicSharded { mesh, .. } => mesh.rows,
        }
    }

    pub fn in_features(&self) -> usize {
        match self {
            ProjEngine::Digital { w, .. } => w.cols,
            ProjEngine::Photonic { mesh, .. } => mesh.cols,
            ProjEngine::PhotonicSharded { mesh, .. } => mesh.cols,
        }
    }

    /// y = W x (x: [in, cols]).
    pub fn forward(&mut self, x: &Mat) -> Mat {
        match self {
            ProjEngine::Digital { w, fwd_mask, .. } => match fwd_mask {
                None => matmul(w, x),
                Some(mask) => {
                    // SWAT-U style: zero masked weights on the forward path.
                    let mut wm = w.clone();
                    for (v, &keep) in wm.data.iter_mut().zip(mask.iter()) {
                        if !keep {
                            *v = 0.0;
                        }
                    }
                    matmul(&wm, x)
                }
            },
            ProjEngine::Photonic { mesh, fwd_mask, .. } => match fwd_mask {
                None => mesh.forward(x),
                Some((keep, scale)) => mesh.forward_masked(x, Some(keep), *scale),
            },
            ProjEngine::PhotonicSharded { mesh, fwd_mask, .. } => match fwd_mask {
                None => mesh.forward(x),
                Some((keep, scale)) => mesh.forward_masked(x, Some(keep), *scale),
            },
        }
    }

    /// Fused conv forward y = W · X_packed: `pack(c0, c1, dst)` produces
    /// column panel `[c0, c1)` of the logical im2col patch matrix on demand
    /// (see `linalg::conv::PatchExtractor`), straight into pool scratch.
    /// Numerically identical to `forward(&im2col(...))` within a SIMD
    /// dispatch level — same per-element accumulation order, same
    /// `MeshStats` — but the `[Cin·K², B·H'·W']` intermediate is never
    /// materialized.
    pub fn forward_packed<P>(&mut self, total_cols: usize, pack: &P) -> Mat
    where
        P: Fn(usize, usize, &mut [f32]) + Sync,
    {
        match self {
            ProjEngine::Digital { w, fwd_mask, .. } => match fwd_mask {
                None => gemm_packed_panels(pool::global(), w, total_cols, pack),
                Some(mask) => {
                    // SWAT-U style: zero masked weights on the forward path.
                    let mut wm = w.clone();
                    for (v, &keep) in wm.data.iter_mut().zip(mask.iter()) {
                        if !keep {
                            *v = 0.0;
                        }
                    }
                    gemm_packed_panels(pool::global(), &wm, total_cols, pack)
                }
            },
            ProjEngine::Photonic { mesh, fwd_mask, .. } => match fwd_mask {
                None => mesh.forward_packed_on(pool::global(), total_cols, pack, None, 1.0),
                Some((keep, scale)) => {
                    mesh.forward_packed_on(pool::global(), total_cols, pack, Some(keep), *scale)
                }
            },
            ProjEngine::PhotonicSharded { mesh, fwd_mask, .. } => match fwd_mask {
                None => mesh.forward_packed_on(pool::global(), total_cols, pack, None, 1.0),
                Some((keep, scale)) => {
                    mesh.forward_packed_on(pool::global(), total_cols, pack, Some(keep), *scale)
                }
            },
        }
    }

    /// Serving entry: y = W·X where X's columns are externally-held
    /// single-sample slices (the serve admission layer's coalesced batch).
    /// Routed through [`ProjEngine::forward_packed`], so the samples are
    /// gathered straight into the GEMM packing buffers and never
    /// materialize as a `[in, batch]` matrix. Because every kernel
    /// accumulates each output element in a fixed k-order independent of
    /// the panel's column count, the result is bitwise identical to
    /// `forward` on the gathered matrix — and each output column is
    /// bitwise identical to a single-sample `forward` of that column —
    /// within one SIMD dispatch level, at every thread count.
    pub fn forward_gathered(&mut self, cols: &[&[f32]]) -> Mat {
        let inp = self.in_features();
        for c in cols {
            assert_eq!(c.len(), inp, "forward_gathered column length");
        }
        self.forward_packed(cols.len(), &|c0: usize, c1: usize, dst: &mut [f32]| {
            // dst is a pre-zeroed row-major [rows, c1 - c0] panel; rows
            // beyond `inp` (mesh padding) must stay zero.
            let wpan = c1 - c0;
            for (j, col) in cols[c0..c1].iter().enumerate() {
                for (r, &v) in col.iter().enumerate() {
                    dst[r * wpan + j] = v;
                }
            }
        })
    }

    /// Backward: given cached input x and upstream dy, accumulate weight/Σ
    /// gradients and return dx. `fb` optionally masks the feedback matrix;
    /// `col_keep` optionally masks gradient-evaluation columns (CS).
    pub fn backward(
        &mut self,
        x: &Mat,
        dy: &Mat,
        fb: Option<&FeedbackMask>,
        col_keep: Option<&[bool]>,
        col_scale: f32,
    ) -> Mat {
        match self {
            ProjEngine::Digital { w, grad_w, .. } => {
                // Full-space: dW += dy·xᵀ (with optional column masking to
                // let the RAD/SWAT baselines reuse this engine), dx = Wᵀ dy.
                // Full-batch fast path: accumulate dy·xᵀ straight into the
                // gradient buffer (§Perf: no per-step temporaries or input
                // clones; the A·Bᵀ kernel zero-skips ReLU-sparse dy rows).
                match col_keep {
                    None if col_scale == 1.0 => matmul_a_bt_acc(dy, x, grad_w),
                    _ => {
                        let gw = match col_keep {
                            None => matmul_a_bt(dy, x),
                            Some(mask) => {
                                let (dys, xs) = (mask_cols(dy, mask), mask_cols(x, mask));
                                matmul_a_bt(&dys, &xs)
                            }
                        };
                        // In-place scaled accumulate — no temporaries beyond
                        // the product itself.
                        for (g, v) in grad_w.data.iter_mut().zip(&gw.data) {
                            *g += col_scale * v;
                        }
                    }
                }
                match fb {
                    None => matmul_at_b(w, dy),
                    Some(m) => {
                        // Blockwise-masked Wᵀ for baseline parity.
                        let wm = m.apply_dense(w);
                        matmul_at_b(&wm, dy)
                    }
                }
            }
            ProjEngine::Photonic { mesh, grad_sigma, .. } => {
                let g = mesh.sigma_grad(x, dy, col_keep, col_scale);
                for (acc, gi) in grad_sigma.iter_mut().zip(&g) {
                    *acc += gi;
                }
                match fb {
                    None => mesh.feedback(dy, None, 1.0),
                    Some(m) => mesh.feedback(dy, Some(&m.keep), m.scale),
                }
            }
            ProjEngine::PhotonicSharded { mesh, grad_sigma, .. } => {
                let g = mesh.sigma_grad(x, dy, col_keep, col_scale);
                for (acc, gi) in grad_sigma.iter_mut().zip(&g) {
                    *acc += gi;
                }
                match fb {
                    None => mesh.feedback(dy, None, 1.0),
                    Some(m) => mesh.feedback(dy, Some(&m.keep), m.scale),
                }
            }
        }
    }

    /// Zero accumulated gradients.
    pub fn zero_grad(&mut self) {
        match self {
            ProjEngine::Digital { grad_w, .. } => grad_w.data.fill(0.0),
            ProjEngine::Photonic { grad_sigma, .. } => grad_sigma.fill(0.0),
            ProjEngine::PhotonicSharded { grad_sigma, .. } => grad_sigma.fill(0.0),
        }
    }

    /// The realized dense weight (digital: exact; photonic: noisy W̃).
    pub fn dense_weight(&mut self) -> Mat {
        match self {
            ProjEngine::Digital { w, .. } => w.clone(),
            ProjEngine::Photonic { mesh, .. } => mesh.to_dense(),
            ProjEngine::PhotonicSharded { mesh, .. } => mesh.to_dense(),
        }
    }

    /// Per-block squared Frobenius norms for the btopk sampler; block grid
    /// (p, q) is (1,1) for digital engines (no blocking).
    pub fn block_norms(&self) -> (usize, usize, Vec<f32>) {
        match self {
            ProjEngine::Digital { w, .. } => (1, 1, vec![w.fro_norm_sq()]),
            ProjEngine::Photonic { mesh, .. } => (mesh.p, mesh.q, mesh.block_norms_sq()),
            ProjEngine::PhotonicSharded { mesh, .. } => (mesh.p, mesh.q, mesh.block_norms_sq()),
        }
    }
}

fn mask_cols(x: &Mat, keep: &[bool]) -> Mat {
    assert_eq!(keep.len(), x.cols);
    let kept: Vec<usize> = (0..x.cols).filter(|&c| keep[c]).collect();
    let mut out = Mat::zeros(x.rows, kept.len());
    for r in 0..x.rows {
        let src = x.row(r);
        let dst = out.row_mut(r);
        for (j, &c) in kept.iter().enumerate() {
            dst[j] = src[c];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::assert_close;

    #[test]
    fn digital_forward_backward_shapes() {
        let mut rng = Rng::new(1);
        let mut eng = ProjEngine::new(EngineKind::Digital, 6, 4, &mut rng);
        let x = Mat::randn(4, 3, 1.0, &mut rng);
        let y = eng.forward(&x);
        assert_eq!((y.rows, y.cols), (6, 3));
        let dy = Mat::randn(6, 3, 1.0, &mut rng);
        let dx = eng.backward(&x, &dy, None, None, 1.0);
        assert_eq!((dx.rows, dx.cols), (4, 3));
        if let ProjEngine::Digital { grad_w, .. } = &eng {
            assert_close(&grad_w.data, &matmul_a_bt(&dy, &x).data, 1e-5, 1e-5).unwrap();
        }
    }

    #[test]
    fn photonic_ideal_matches_digital_forward() {
        let mut rng = Rng::new(2);
        let kind = EngineKind::Photonic { k: 4, noise: NoiseModel::IDEAL };
        let mut eng = ProjEngine::new(kind, 8, 8, &mut rng);
        let w = eng.dense_weight();
        let x = Mat::randn(8, 5, 1.0, &mut rng);
        let y = eng.forward(&x);
        assert_close(&y.data, &matmul(&w, &x).data, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn photonic_grad_is_subspace_only() {
        let mut rng = Rng::new(3);
        let kind = EngineKind::Photonic { k: 3, noise: NoiseModel::IDEAL };
        let mut eng = ProjEngine::new(kind, 6, 6, &mut rng);
        let x = Mat::randn(6, 4, 1.0, &mut rng);
        let dy = Mat::randn(6, 4, 1.0, &mut rng);
        eng.backward(&x, &dy, None, None, 1.0);
        if let ProjEngine::Photonic { grad_sigma, mesh, .. } = &eng {
            assert_eq!(grad_sigma.len(), mesh.n_sigma());
            assert!(grad_sigma.iter().any(|&g| g != 0.0));
        } else {
            panic!("expected photonic")
        }
        eng.zero_grad();
        if let ProjEngine::Photonic { grad_sigma, .. } = &eng {
            assert!(grad_sigma.iter().all(|&g| g == 0.0));
        }
    }

    #[test]
    fn forward_gathered_is_bitwise_forward() {
        // The serving entry must equal the matrix forward bitwise, and
        // each column must equal its own single-sample forward bitwise —
        // the foundation of tests/serve_equivalence.rs.
        let mut rng = Rng::new(7);
        for kind in [EngineKind::Digital, EngineKind::Photonic { k: 4, noise: NoiseModel::PAPER }]
        {
            let mut eng = ProjEngine::new(kind, 10, 6, &mut rng);
            let x = Mat::randn(6, 9, 1.0, &mut rng);
            let cols: Vec<Vec<f32>> = (0..x.cols)
                .map(|c| (0..x.rows).map(|r| x.data[r * x.cols + c]).collect())
                .collect();
            let views: Vec<&[f32]> = cols.iter().map(|c| c.as_slice()).collect();
            let y_ref = eng.forward(&x);
            let y_gat = eng.forward_gathered(&views);
            assert_eq!(y_ref.data, y_gat.data, "{kind:?}: gathered != matrix forward");
            for (c, col) in views.iter().enumerate() {
                let y1 = eng.forward_gathered(&[col]);
                for r in 0..y_ref.rows {
                    assert_eq!(
                        y_ref.data[r * y_ref.cols + c],
                        y1.data[r],
                        "{kind:?}: column {c} not batch-size invariant"
                    );
                }
            }
        }
    }

    #[test]
    fn digital_column_masking_scales() {
        // With all columns kept and scale 1, masked == unmasked.
        let mut rng = Rng::new(4);
        let mut e1 = ProjEngine::new(EngineKind::Digital, 5, 5, &mut rng);
        let mut e2 = e1.clone();
        let x = Mat::randn(5, 6, 1.0, &mut rng);
        let dy = Mat::randn(5, 6, 1.0, &mut rng);
        e1.backward(&x, &dy, None, None, 1.0);
        e2.backward(&x, &dy, None, Some(&vec![true; 6]), 1.0);
        match (&e1, &e2) {
            (ProjEngine::Digital { grad_w: g1, .. }, ProjEngine::Digital { grad_w: g2, .. }) => {
                assert_close(&g1.data, &g2.data, 1e-6, 1e-6).unwrap();
            }
            _ => unreachable!(),
        }
    }
}
