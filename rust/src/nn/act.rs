//! Feature-major activation tensor: a [C, B·H·W] matrix with NCHW metadata.
//! Column index is `b·(H·W) + h·W + w`. Linear activations use H=W=1.

use crate::linalg::Mat;

/// Activation tensor.
#[derive(Clone, Debug)]
pub struct Act {
    /// [channels, batch · h · w]
    pub mat: Mat,
    pub batch: usize,
    pub h: usize,
    pub w: usize,
}

impl Act {
    /// Feature-vector activations [features, batch].
    pub fn from_features(mat: Mat, batch: usize) -> Act {
        assert_eq!(mat.cols, batch, "feature act cols == batch");
        Act { mat, batch, h: 1, w: 1 }
    }

    /// Image activations [C, B·H·W].
    pub fn from_image(mat: Mat, batch: usize, h: usize, w: usize) -> Act {
        assert_eq!(mat.cols, batch * h * w, "image act cols");
        Act { mat, batch, h, w }
    }

    pub fn channels(&self) -> usize {
        self.mat.rows
    }

    pub fn spatial(&self) -> usize {
        self.h * self.w
    }

    /// Same-shape zero tensor.
    pub fn zeros_like(&self) -> Act {
        Act { mat: Mat::zeros(self.mat.rows, self.mat.cols), ..*self }
    }

    /// Convert to flat NCHW layout (for im2col and dataset interop).
    pub fn to_nchw(&self) -> Vec<f32> {
        let (c, s) = (self.channels(), self.spatial());
        let mut out = vec![0.0f32; self.batch * c * s];
        for ch in 0..c {
            let row = self.mat.row(ch);
            for b in 0..self.batch {
                let src = &row[b * s..(b + 1) * s];
                out[(b * c + ch) * s..(b * c + ch + 1) * s].copy_from_slice(src);
            }
        }
        out
    }

    /// Build from flat NCHW.
    pub fn from_nchw(data: &[f32], batch: usize, c: usize, h: usize, w: usize) -> Act {
        assert_eq!(data.len(), batch * c * h * w, "from_nchw size");
        let s = h * w;
        let mut mat = Mat::zeros(c, batch * s);
        for ch in 0..c {
            let row = mat.row_mut(ch);
            for b in 0..batch {
                row[b * s..(b + 1) * s]
                    .copy_from_slice(&data[(b * c + ch) * s..(b * c + ch + 1) * s]);
            }
        }
        Act { mat, batch, h, w }
    }

    /// Flatten an image activation [C, B·S] into a feature activation
    /// [C·S, B] (channel-major features, matching PyTorch's flatten order).
    pub fn flatten(&self) -> Act {
        let (c, s, b) = (self.channels(), self.spatial(), self.batch);
        let mut mat = Mat::zeros(c * s, b);
        for ch in 0..c {
            let src = self.mat.row(ch);
            for sp in 0..s {
                let dst = mat.row_mut(ch * s + sp);
                for bi in 0..b {
                    dst[bi] = src[bi * s + sp];
                }
            }
        }
        Act::from_features(mat, b)
    }

    /// Inverse of `flatten` (for the backward pass).
    pub fn unflatten(&self, c: usize, h: usize, w: usize) -> Act {
        let s = h * w;
        assert_eq!(self.mat.rows, c * s, "unflatten rows");
        assert_eq!(self.h * self.w, 1, "unflatten expects feature act");
        let b = self.batch;
        let mut mat = Mat::zeros(c, b * s);
        for ch in 0..c {
            let dst = mat.row_mut(ch);
            for sp in 0..s {
                let src = self.mat.row(ch * s + sp);
                for bi in 0..b {
                    dst[bi * s + sp] = src[bi];
                }
            }
        }
        Act::from_image(mat, b, h, w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_close, quickcheck};

    #[test]
    fn nchw_roundtrip() {
        quickcheck(
            "act nchw roundtrip",
            |rng, size| {
                let b = 1 + size % 3;
                let c = 1 + size % 5;
                let h = 1 + size % 4;
                let w = 1 + size % 4;
                let data: Vec<f32> = (0..b * c * h * w).map(|_| rng.normal() as f32).collect();
                (data, b, c, h, w)
            },
            |(data, b, c, h, w)| {
                let act = Act::from_nchw(data, *b, *c, *h, *w);
                assert_close(&act.to_nchw(), data, 0.0, 0.0)
            },
        );
    }

    #[test]
    fn flatten_matches_pytorch_order() {
        // B=1, C=2, H=W=2: NCHW flat = [c0s0 c0s1 c0s2 c0s3 c1s0 ...];
        // flatten -> features in the same order.
        let data: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let act = Act::from_nchw(&data, 1, 2, 2, 2);
        let flat = act.flatten();
        assert_eq!(flat.mat.rows, 8);
        assert_eq!(flat.batch, 1);
        let col: Vec<f32> = (0..8).map(|r| flat.mat[(r, 0)]).collect();
        assert_eq!(col, data);
    }

    #[test]
    fn flatten_unflatten_roundtrip() {
        quickcheck(
            "flatten/unflatten roundtrip",
            |rng, size| {
                let b = 1 + size % 3;
                let c = 1 + size % 4;
                let h = 1 + size % 3;
                let data: Vec<f32> = (0..b * c * h * h).map(|_| rng.normal() as f32).collect();
                (data, b, c, h)
            },
            |(data, b, c, h)| {
                let act = Act::from_nchw(data, *b, *c, *h, *h);
                let rt = act.flatten().unflatten(*c, *h, *h);
                assert_close(&rt.mat.data, &act.mat.data, 0.0, 0.0)
            },
        );
    }
}
