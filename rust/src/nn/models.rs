//! The paper's model zoo (§4.1): MLP (8-16-16-4) for Vowel, CNN-S for MNIST,
//! CNN-L for FashionMNIST, VGG-8 and ResNet-18 for CIFAR-10/100.
//!
//! Every architecture takes a width multiplier so the same topology can run
//! full-size (paper scale) or scaled-down (CPU-budget experiments); the
//! experiment harness records which width was used.

use super::engine::{EngineKind, ProjEngine};
use super::layers::{
    AvgPool, BatchNorm, Conv2d, Flatten, GlobalAvgPool, Layer, Linear, MaxPool, Relu,
};
use super::model::{Model, Node};
use crate::util::Rng;

/// Architectures evaluated in the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelArch {
    /// 8-16-16-4 MLP (Vowel) [17].
    MlpVowel,
    /// CONV8K3S2-CONV6K3S2-FC10 (MNIST) [17].
    CnnS,
    /// {CONV64K3}×3-Pool5-FC10 (FashionMNIST).
    CnnL,
    /// VGG-8 (6 conv + 2 FC) for CIFAR.
    Vgg8,
    /// ResNet-18 (CIFAR variant).
    ResNet18,
}

impl ModelArch {
    pub fn parse(name: &str) -> Option<ModelArch> {
        Some(match name {
            "mlp" | "mlp-vowel" => ModelArch::MlpVowel,
            "cnn-s" | "cnns" => ModelArch::CnnS,
            "cnn-l" | "cnnl" => ModelArch::CnnL,
            "vgg8" | "vgg-8" => ModelArch::Vgg8,
            "resnet18" | "resnet-18" => ModelArch::ResNet18,
            _ => return None,
        })
    }

    /// (input channels, input H=W) expected by the architecture.
    pub fn input_spec(&self) -> (usize, usize) {
        match self {
            ModelArch::MlpVowel => (8, 1), // feature vector of 8
            ModelArch::CnnS => (1, 28),
            ModelArch::CnnL => (1, 28),
            ModelArch::Vgg8 | ModelArch::ResNet18 => (3, 32),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ModelArch::MlpVowel => "mlp-vowel",
            ModelArch::CnnS => "cnn-s",
            ModelArch::CnnL => "cnn-l",
            ModelArch::Vgg8 => "vgg8",
            ModelArch::ResNet18 => "resnet18",
        }
    }
}

fn scaled(c: usize, width: f32) -> usize {
    ((c as f32 * width).round() as usize).max(4)
}

fn conv(
    kind: EngineKind,
    in_ch: usize,
    out_ch: usize,
    k: usize,
    stride: usize,
    pad: usize,
    rng: &mut Rng,
) -> Node {
    let eng = ProjEngine::new(kind, out_ch, in_ch * k * k, rng);
    Node::Plain(Layer::Conv2d(Conv2d::new(eng, in_ch, out_ch, k, stride, pad)))
}

fn linear(kind: EngineKind, inp: usize, out: usize, rng: &mut Rng) -> Node {
    Node::Plain(Layer::Linear(Linear::new(ProjEngine::new(kind, out, inp, rng))))
}

fn bn(c: usize) -> Node {
    Node::Plain(Layer::BatchNorm(BatchNorm::new(c)))
}

fn relu() -> Node {
    Node::Plain(Layer::Relu(Relu::new()))
}

/// Build an architecture with the given projection engine kind, class count,
/// and width multiplier.
pub fn build_model(
    arch: ModelArch,
    kind: EngineKind,
    classes: usize,
    width: f32,
    rng: &mut Rng,
) -> Model {
    match arch {
        ModelArch::MlpVowel => {
            let h = scaled(16, width);
            Model::new(
                arch.name(),
                vec![
                    linear(kind, 8, h, rng),
                    relu(),
                    linear(kind, h, h, rng),
                    relu(),
                    linear(kind, h, classes, rng),
                ],
            )
        }
        ModelArch::CnnS => {
            let (c1, c2) = (scaled(8, width), scaled(6, width));
            // 28 → 14 → 7 with k3 s2 p1.
            Model::new(
                arch.name(),
                vec![
                    conv(kind, 1, c1, 3, 2, 1, rng),
                    bn(c1),
                    relu(),
                    conv(kind, c1, c2, 3, 2, 1, rng),
                    bn(c2),
                    relu(),
                    Node::Plain(Layer::Flatten(Flatten::new())),
                    linear(kind, c2 * 7 * 7, classes, rng),
                ],
            )
        }
        ModelArch::CnnL => {
            let c = scaled(64, width);
            let mut nodes = Vec::new();
            let mut in_ch = 1;
            for _ in 0..3 {
                nodes.push(conv(kind, in_ch, c, 3, 1, 1, rng));
                nodes.push(bn(c));
                nodes.push(relu());
                in_ch = c;
            }
            // 28 → Pool5 → 5 (floor division, matches stride=kernel pooling).
            nodes.push(Node::Plain(Layer::AvgPool(AvgPool::new(5))));
            nodes.push(Node::Plain(Layer::Flatten(Flatten::new())));
            nodes.push(linear(kind, c * 5 * 5, classes, rng));
            Model::new(arch.name(), nodes)
        }
        ModelArch::Vgg8 => {
            // conv64-M-conv128-M-conv256x2-M-conv512x2-M, FC512, FCc — the
            // common CIFAR VGG-8 (6 conv + 2 FC weighted layers) [8].
            let (c1, c2, c3, c4) =
                (scaled(64, width), scaled(128, width), scaled(256, width), scaled(512, width));
            let mut n = Vec::new();
            n.push(conv(kind, 3, c1, 3, 1, 1, rng));
            n.push(bn(c1));
            n.push(relu());
            n.push(Node::Plain(Layer::MaxPool(MaxPool::new(2)))); // 32→16
            n.push(conv(kind, c1, c2, 3, 1, 1, rng));
            n.push(bn(c2));
            n.push(relu());
            n.push(Node::Plain(Layer::MaxPool(MaxPool::new(2)))); // 16→8
            n.push(conv(kind, c2, c3, 3, 1, 1, rng));
            n.push(bn(c3));
            n.push(relu());
            n.push(conv(kind, c3, c3, 3, 1, 1, rng));
            n.push(bn(c3));
            n.push(relu());
            n.push(Node::Plain(Layer::MaxPool(MaxPool::new(2)))); // 8→4
            n.push(conv(kind, c3, c4, 3, 1, 1, rng));
            n.push(bn(c4));
            n.push(relu());
            n.push(conv(kind, c4, c4, 3, 1, 1, rng));
            n.push(bn(c4));
            n.push(relu());
            n.push(Node::Plain(Layer::MaxPool(MaxPool::new(2)))); // 4→2
            n.push(Node::Plain(Layer::GlobalAvgPool(GlobalAvgPool::new())));
            n.push(Node::Plain(Layer::Flatten(Flatten::new())));
            n.push(linear(kind, c4, scaled(512, width), rng));
            n.push(relu());
            n.push(linear(kind, scaled(512, width), classes, rng));
            Model::new(arch.name(), n)
        }
        ModelArch::ResNet18 => {
            let widths = [scaled(64, width), scaled(128, width), scaled(256, width),
                scaled(512, width)];
            let mut n = Vec::new();
            n.push(conv(kind, 3, widths[0], 3, 1, 1, rng));
            n.push(bn(widths[0]));
            n.push(relu());
            let mut in_ch = widths[0];
            for (stage, &ch) in widths.iter().enumerate() {
                let stride0 = if stage == 0 { 1 } else { 2 };
                for blk in 0..2 {
                    let stride = if blk == 0 { stride0 } else { 1 };
                    n.push(basic_block(kind, in_ch, ch, stride, rng));
                    n.push(relu());
                    in_ch = ch;
                }
            }
            n.push(Node::Plain(Layer::GlobalAvgPool(GlobalAvgPool::new())));
            n.push(Node::Plain(Layer::Flatten(Flatten::new())));
            n.push(linear(kind, in_ch, classes, rng));
            Model::new(arch.name(), n)
        }
    }
}

/// ResNet basic block: conv-bn-relu-conv-bn with identity or 1×1 downsample.
fn basic_block(
    kind: EngineKind,
    in_ch: usize,
    out_ch: usize,
    stride: usize,
    rng: &mut Rng,
) -> Node {
    let body = vec![
        conv(kind, in_ch, out_ch, 3, stride, 1, rng),
        bn(out_ch),
        relu(),
        conv(kind, out_ch, out_ch, 3, 1, 1, rng),
        bn(out_ch),
    ];
    let shortcut = if stride != 1 || in_ch != out_ch {
        vec![conv(kind, in_ch, out_ch, 1, stride, 0, rng), bn(out_ch)]
    } else {
        vec![]
    };
    Node::Residual { body, shortcut }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::nn::act::Act;
    use crate::nn::model::BackwardCtx;

    fn smoke(arch: ModelArch, classes: usize, width: f32) {
        let mut rng = Rng::new(42);
        let mut m = build_model(arch, EngineKind::Digital, classes, width, &mut rng);
        let (c, hw) = arch.input_spec();
        let b = 2;
        let x = if hw == 1 {
            Act::from_features(Mat::randn(c, b, 1.0, &mut rng), b)
        } else {
            Act::from_nchw(
                &(0..b * c * hw * hw).map(|_| rng.normal() as f32).collect::<Vec<_>>(),
                b,
                c,
                hw,
                hw,
            )
        };
        let y = m.forward(&x, true);
        assert_eq!(y.mat.rows, classes, "{arch:?} logits");
        assert_eq!(y.mat.cols, b);
        assert!(y.mat.data.iter().all(|v| v.is_finite()), "{arch:?} NaN");
        // Backward smoke.
        let mut ctx = BackwardCtx::plain(Rng::new(1));
        let dx = m.backward(&y, &mut ctx);
        assert_eq!(dx.mat.rows, x.mat.rows, "{arch:?} dx");
        assert!(dx.mat.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn mlp_shapes() {
        smoke(ModelArch::MlpVowel, 4, 1.0);
    }

    #[test]
    fn cnn_s_shapes() {
        smoke(ModelArch::CnnS, 10, 1.0);
    }

    #[test]
    fn cnn_l_shapes() {
        smoke(ModelArch::CnnL, 10, 0.25);
    }

    #[test]
    fn vgg8_shapes() {
        smoke(ModelArch::Vgg8, 10, 0.125);
    }

    #[test]
    fn resnet18_shapes() {
        smoke(ModelArch::ResNet18, 10, 0.125);
    }

    #[test]
    fn parse_names() {
        assert_eq!(ModelArch::parse("vgg8"), Some(ModelArch::Vgg8));
        assert_eq!(ModelArch::parse("resnet-18"), Some(ModelArch::ResNet18));
        assert_eq!(ModelArch::parse("nope"), None);
    }

    #[test]
    fn width_scaling_changes_params() {
        let mut rng = Rng::new(1);
        let mut a = build_model(ModelArch::CnnL, EngineKind::Digital, 10, 1.0, &mut rng);
        let mut b = build_model(ModelArch::CnnL, EngineKind::Digital, 10, 0.25, &mut rng);
        assert!(a.param_counts().1 > 4 * b.param_counts().1);
    }
}
