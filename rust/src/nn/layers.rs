//! Layer implementations with explicit forward/backward. Projection layers
//! (Linear, Conv2d) delegate the matrix product to a `ProjEngine` and thread
//! the §3.4.2 sampling machinery through `BackwardCtx`.

use super::act::Act;
use super::engine::ProjEngine;
use super::model::BackwardCtx;
use crate::linalg::{col2im_pooled, im2col_pooled, Conv2dShape, Mat, PatchExtractor};

/// A single layer.
#[derive(Clone, Debug)]
pub enum Layer {
    Linear(Linear),
    Conv2d(Conv2d),
    Relu(Relu),
    BatchNorm(BatchNorm),
    AvgPool(AvgPool),
    MaxPool(MaxPool),
    GlobalAvgPool(GlobalAvgPool),
    Flatten(Flatten),
}

impl Layer {
    pub fn forward(&mut self, x: &Act, train: bool) -> Act {
        match self {
            Layer::Linear(l) => l.forward(x, train),
            Layer::Conv2d(l) => l.forward(x, train),
            Layer::Relu(l) => l.forward(x, train),
            Layer::BatchNorm(l) => l.forward(x, train),
            Layer::AvgPool(l) => l.forward(x, train),
            Layer::MaxPool(l) => l.forward(x, train),
            Layer::GlobalAvgPool(l) => l.forward(x, train),
            Layer::Flatten(l) => l.forward(x, train),
        }
    }

    pub fn backward(&mut self, dy: &Act, ctx: &mut BackwardCtx) -> Act {
        match self {
            Layer::Linear(l) => l.backward(dy, ctx),
            Layer::Conv2d(l) => l.backward(dy, ctx),
            Layer::Relu(l) => l.backward(dy),
            Layer::BatchNorm(l) => l.backward(dy),
            Layer::AvgPool(l) => l.backward(dy),
            Layer::MaxPool(l) => l.backward(dy),
            Layer::GlobalAvgPool(l) => l.backward(dy),
            Layer::Flatten(l) => l.backward(dy),
        }
    }

    /// Projection engine if this layer has one.
    pub fn engine_mut(&mut self) -> Option<&mut ProjEngine> {
        match self {
            Layer::Linear(l) => Some(&mut l.engine),
            Layer::Conv2d(l) => Some(&mut l.engine),
            _ => None,
        }
    }

    pub fn engine(&self) -> Option<&ProjEngine> {
        match self {
            Layer::Linear(l) => Some(&l.engine),
            Layer::Conv2d(l) => Some(&l.engine),
            _ => None,
        }
    }

    /// Drop cached forward state (frees memory between epochs / for eval).
    pub fn clear_cache(&mut self) {
        match self {
            Layer::Linear(l) => l.cache = None,
            Layer::Conv2d(l) => {
                l.cache_x = None;
                l.cache_shape = None;
            }
            Layer::Relu(l) => l.mask = None,
            Layer::BatchNorm(l) => l.cache = None,
            Layer::AvgPool(l) => l.cache = None,
            Layer::MaxPool(l) => l.cache = None,
            Layer::GlobalAvgPool(l) => l.cache = None,
            Layer::Flatten(l) => l.cache = None,
        }
    }
}

// ---------------------------------------------------------------------------
// Linear
// ---------------------------------------------------------------------------

/// Fully-connected layer y = W x + b over feature activations [F, B].
#[derive(Clone, Debug)]
pub struct Linear {
    pub engine: ProjEngine,
    pub bias: Vec<f32>,
    pub grad_bias: Vec<f32>,
    cache: Option<Mat>,
}

impl Linear {
    pub fn new(engine: ProjEngine) -> Linear {
        let out = engine.out_features();
        Linear { engine, bias: vec![0.0; out], grad_bias: vec![0.0; out], cache: None }
    }

    pub fn forward(&mut self, x: &Act, train: bool) -> Act {
        assert_eq!(x.spatial(), 1, "Linear expects feature activations");
        let mut y = self.engine.forward(&x.mat);
        for (r, &b) in self.bias.iter().enumerate() {
            for v in y.row_mut(r) {
                *v += b;
            }
        }
        if train {
            self.cache = Some(x.mat.clone());
        }
        Act::from_features(y, x.batch)
    }

    /// Serving fast path: forward a coalesced panel of single-sample
    /// columns without materializing the `[features, batch]` input
    /// (`ProjEngine::forward_gathered` packs them straight into GEMM
    /// panels). Eval-only — nothing is cached for backward. Bitwise
    /// identical to `forward` on the gathered activation within a SIMD
    /// dispatch level.
    pub fn forward_gathered(&mut self, cols: &[&[f32]]) -> Act {
        let mut y = self.engine.forward_gathered(cols);
        for (r, &b) in self.bias.iter().enumerate() {
            for v in y.row_mut(r) {
                *v += b;
            }
        }
        Act::from_features(y, cols.len())
    }

    pub fn backward(&mut self, dy: &Act, ctx: &mut BackwardCtx) -> Act {
        for (r, g) in self.grad_bias.iter_mut().enumerate() {
            *g += dy.mat.row(r).iter().sum::<f32>();
        }
        let fb = ctx.draw_feedback(&self.engine);
        // CS degenerates to batch sampling for FC layers; the paper applies
        // it to CONV layers only, so no column mask here. The cached input
        // is borrowed, not cloned (§Perf: engine and cache are disjoint
        // fields).
        let x = self.cache.as_ref().expect("Linear backward without forward");
        let dx = self.engine.backward(x, &dy.mat, fb.as_ref(), None, 1.0);
        Act::from_features(dx, dy.batch)
    }
}

// ---------------------------------------------------------------------------
// Conv2d
// ---------------------------------------------------------------------------

/// 2-D convolution lowered to im2col + blocked projection. The forward
/// path is fused (§Perf): patch panels are extracted straight into the GEMM
/// packing buffers via `ProjEngine::forward_packed`, so the `[Cin·K²,
/// B·H'·W']` patch matrix is never materialized on forward. The backward
/// σ-/weight-gradient API consumes a whole patch matrix, so it is built
/// lazily on first backward (`im2col_pooled`) and the input-gradient fold
/// runs per-plane-parallel (`col2im_pooled`).
#[derive(Clone, Debug)]
pub struct Conv2d {
    pub engine: ProjEngine,
    pub in_ch: usize,
    pub out_ch: usize,
    pub kernel: usize,
    pub stride: usize,
    pub padding: usize,
    pub bias: Vec<f32>,
    pub grad_bias: Vec<f32>,
    /// im2col patch matrix, materialized lazily by the first backward (the
    /// fused forward never builds it; recomputed under SS).
    cache_x: Option<Mat>,
    cache_shape: Option<Conv2dShape>,
    /// Cached raw input (the source for the lazy patch materialization and
    /// for spatial-sampling re-unfolds).
    cache_input: Option<Act>,
}

impl Conv2d {
    pub fn new(
        engine: ProjEngine,
        in_ch: usize,
        out_ch: usize,
        kernel: usize,
        stride: usize,
        padding: usize,
    ) -> Conv2d {
        assert_eq!(engine.out_features(), out_ch);
        assert_eq!(engine.in_features(), in_ch * kernel * kernel);
        Conv2d {
            engine,
            in_ch,
            out_ch,
            kernel,
            stride,
            padding,
            bias: vec![0.0; out_ch],
            grad_bias: vec![0.0; out_ch],
            cache_x: None,
            cache_shape: None,
            cache_input: None,
        }
    }

    fn shape_for(&self, x: &Act) -> Conv2dShape {
        Conv2dShape {
            batch: x.batch,
            in_ch: self.in_ch,
            in_h: x.h,
            in_w: x.w,
            out_ch: self.out_ch,
            kernel: self.kernel,
            stride: self.stride,
            padding: self.padding,
        }
    }

    pub fn forward(&mut self, x: &Act, train: bool) -> Act {
        assert_eq!(x.channels(), self.in_ch, "Conv2d input channels");
        let sh = self.shape_for(x);
        // Fused packed-panel path: patch panels go straight from the NCHW
        // activation into pool-scratch GEMM packing buffers (bitwise equal
        // to forward(&im2col(..)) within a SIMD dispatch level).
        let nchw = x.to_nchw();
        let ex = PatchExtractor::new(&nchw, &sh);
        let mut y = self
            .engine
            .forward_packed(sh.patch_cols(), &|c0, c1, dst: &mut [f32]| ex.pack_into(c0, c1, dst));
        for (r, &b) in self.bias.iter().enumerate() {
            for v in y.row_mut(r) {
                *v += b;
            }
        }
        if train {
            self.cache_x = None; // built lazily by backward
            self.cache_shape = Some(sh);
            self.cache_input = Some(x.clone());
        }
        Act::from_image(y, x.batch, sh.out_h(), sh.out_w())
    }

    pub fn backward(&mut self, dy: &Act, ctx: &mut BackwardCtx) -> Act {
        let sh = *self.cache_shape.as_ref().expect("Conv2d backward without forward");
        for (r, g) in self.grad_bias.iter_mut().enumerate() {
            *g += dy.mat.row(r).iter().sum::<f32>();
        }
        // Feature sampling: CS masks patch columns; SS re-unfolds a
        // pixel-sparsified input (no structured savings — the point of Fig 9).
        let col_mask = ctx.feature.draw_column_mask(sh.batch, sh.out_h() * sh.out_w(), &mut ctx.rng);
        let recomputed = ctx
            .feature
            .apply_spatial(self.cache_input.as_ref().unwrap(), &mut ctx.rng)
            .map(|sparse_in| im2col_pooled(&sparse_in.to_nchw(), &sh));
        // The gradient API consumes a whole patch matrix; on the common
        // (no-SS) path materialize it lazily from the cached input — the
        // fused forward never built it — and keep it for repeat backwards.
        if recomputed.is_none() && self.cache_x.is_none() {
            let nchw =
                self.cache_input.as_ref().expect("Conv2d backward without forward").to_nchw();
            self.cache_x = Some(im2col_pooled(&nchw, &sh));
        }
        let x_for_grad: &Mat = recomputed.as_ref().unwrap_or_else(|| self.cache_x.as_ref().unwrap());
        let fb = ctx.draw_feedback(&self.engine);
        let dx_cols = self.engine.backward(
            x_for_grad,
            &dy.mat,
            fb.as_ref(),
            col_mask.as_deref(),
            ctx.feature.scale(),
        );
        let dx_nchw = col2im_pooled(&dx_cols, &sh);
        Act::from_nchw(&dx_nchw, sh.batch, sh.in_ch, sh.in_h, sh.in_w)
    }
}

// ---------------------------------------------------------------------------
// ReLU
// ---------------------------------------------------------------------------

/// Rectified linear unit.
#[derive(Clone, Debug, Default)]
pub struct Relu {
    mask: Option<Vec<bool>>,
}

impl Relu {
    pub fn new() -> Relu {
        Relu { mask: None }
    }

    pub fn forward(&mut self, x: &Act, train: bool) -> Act {
        let mut y = x.clone();
        if train {
            let mask: Vec<bool> = y.mat.data.iter().map(|&v| v > 0.0).collect();
            self.mask = Some(mask);
        }
        for v in &mut y.mat.data {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        y
    }

    pub fn backward(&mut self, dy: &Act) -> Act {
        let mask = self.mask.as_ref().expect("Relu backward without forward");
        let mut dx = dy.clone();
        for (v, &m) in dx.mat.data.iter_mut().zip(mask) {
            if !m {
                *v = 0.0;
            }
        }
        dx
    }
}

// ---------------------------------------------------------------------------
// BatchNorm (2d — per channel over batch × spatial)
// ---------------------------------------------------------------------------

/// Batch normalization with affine parameters (digital-domain, trainable in
/// both pretraining and on-chip subspace learning — the BN arithmetic lives
/// in the electrical control plane).
#[derive(Clone, Debug)]
pub struct BatchNorm {
    pub gamma: Vec<f32>,
    pub beta: Vec<f32>,
    pub grad_gamma: Vec<f32>,
    pub grad_beta: Vec<f32>,
    pub running_mean: Vec<f32>,
    pub running_var: Vec<f32>,
    pub momentum: f32,
    pub eps: f32,
    cache: Option<BnCache>,
}

#[derive(Clone, Debug)]
struct BnCache {
    x_hat: Mat,
    inv_std: Vec<f32>,
}

impl BatchNorm {
    pub fn new(channels: usize) -> BatchNorm {
        BatchNorm {
            gamma: vec![1.0; channels],
            beta: vec![0.0; channels],
            grad_gamma: vec![0.0; channels],
            grad_beta: vec![0.0; channels],
            running_mean: vec![0.0; channels],
            running_var: vec![1.0; channels],
            momentum: 0.1,
            eps: 1e-5,
            cache: None,
        }
    }

    pub fn forward(&mut self, x: &Act, train: bool) -> Act {
        let c = x.channels();
        assert_eq!(c, self.gamma.len(), "BatchNorm channels");
        let n = x.mat.cols as f32;
        let mut y = x.clone();
        if train {
            let mut x_hat = Mat::zeros(c, x.mat.cols);
            let mut inv_std = vec![0.0f32; c];
            for ch in 0..c {
                let row = x.mat.row(ch);
                let mean = row.iter().sum::<f32>() / n;
                let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n;
                let istd = 1.0 / (var + self.eps).sqrt();
                inv_std[ch] = istd;
                self.running_mean[ch] =
                    (1.0 - self.momentum) * self.running_mean[ch] + self.momentum * mean;
                self.running_var[ch] =
                    (1.0 - self.momentum) * self.running_var[ch] + self.momentum * var;
                let xh = x_hat.row_mut(ch);
                let yr = y.mat.row_mut(ch);
                for (i, &v) in row.iter().enumerate() {
                    let h = (v - mean) * istd;
                    xh[i] = h;
                    yr[i] = self.gamma[ch] * h + self.beta[ch];
                }
            }
            self.cache = Some(BnCache { x_hat, inv_std });
        } else {
            for ch in 0..c {
                let mean = self.running_mean[ch];
                let istd = 1.0 / (self.running_var[ch] + self.eps).sqrt();
                let (g, b) = (self.gamma[ch], self.beta[ch]);
                for v in y.mat.row_mut(ch) {
                    *v = g * (*v - mean) * istd + b;
                }
            }
        }
        y
    }

    pub fn backward(&mut self, dy: &Act) -> Act {
        let cache = self.cache.as_ref().expect("BatchNorm backward without forward");
        let c = dy.channels();
        let n = dy.mat.cols as f32;
        let mut dx = dy.zeros_like();
        for ch in 0..c {
            let dyr = dy.mat.row(ch);
            let xh = cache.x_hat.row(ch);
            let sum_dy: f32 = dyr.iter().sum();
            let sum_dy_xh: f32 = dyr.iter().zip(xh).map(|(a, b)| a * b).sum();
            self.grad_beta[ch] += sum_dy;
            self.grad_gamma[ch] += sum_dy_xh;
            let g_istd_n = self.gamma[ch] * cache.inv_std[ch] / n;
            let dxr = dx.mat.row_mut(ch);
            for i in 0..dyr.len() {
                dxr[i] = g_istd_n * (n * dyr[i] - sum_dy - xh[i] * sum_dy_xh);
            }
        }
        dx
    }
}

// ---------------------------------------------------------------------------
// Pooling
// ---------------------------------------------------------------------------

/// Average pooling with stride == kernel.
#[derive(Clone, Debug)]
pub struct AvgPool {
    pub kernel: usize,
    cache: Option<(usize, usize, usize)>, // (h, w, batch)
}

impl AvgPool {
    pub fn new(kernel: usize) -> AvgPool {
        AvgPool { kernel, cache: None }
    }

    pub fn forward(&mut self, x: &Act, _train: bool) -> Act {
        let k = self.kernel;
        assert!(x.h >= k && x.w >= k, "AvgPool input smaller than kernel");
        let (oh, ow) = (x.h / k, x.w / k);
        let mut y = Mat::zeros(x.channels(), x.batch * oh * ow);
        let inv = 1.0 / (k * k) as f32;
        for ch in 0..x.channels() {
            let src = x.mat.row(ch);
            let dst = y.row_mut(ch);
            for b in 0..x.batch {
                for orow in 0..oh {
                    for ocol in 0..ow {
                        let mut s = 0.0f32;
                        for dr in 0..k {
                            for dc in 0..k {
                                s += src[b * x.h * x.w + (orow * k + dr) * x.w + ocol * k + dc];
                            }
                        }
                        dst[b * oh * ow + orow * ow + ocol] = s * inv;
                    }
                }
            }
        }
        self.cache = Some((x.h, x.w, x.batch));
        Act::from_image(y, x.batch, oh, ow)
    }

    pub fn backward(&mut self, dy: &Act) -> Act {
        let (h, w, batch) = self.cache.expect("AvgPool backward without forward");
        let k = self.kernel;
        let (oh, ow) = (dy.h, dy.w);
        let inv = 1.0 / (k * k) as f32;
        let mut dx = Mat::zeros(dy.channels(), batch * h * w);
        for ch in 0..dy.channels() {
            let src = dy.mat.row(ch);
            let dst = dx.row_mut(ch);
            for b in 0..batch {
                for orow in 0..oh {
                    for ocol in 0..ow {
                        let g = src[b * oh * ow + orow * ow + ocol] * inv;
                        for dr in 0..k {
                            for dc in 0..k {
                                dst[b * h * w + (orow * k + dr) * w + ocol * k + dc] += g;
                            }
                        }
                    }
                }
            }
        }
        Act::from_image(dx, batch, h, w)
    }
}

/// Max pooling with stride == kernel.
#[derive(Clone, Debug)]
pub struct MaxPool {
    pub kernel: usize,
    cache: Option<(Vec<usize>, usize, usize, usize)>, // (argmax per out, h, w, batch)
}

impl MaxPool {
    pub fn new(kernel: usize) -> MaxPool {
        MaxPool { kernel, cache: None }
    }

    pub fn forward(&mut self, x: &Act, _train: bool) -> Act {
        let k = self.kernel;
        assert!(x.h >= k && x.w >= k, "MaxPool input smaller than kernel");
        let (oh, ow) = (x.h / k, x.w / k);
        let c = x.channels();
        let mut y = Mat::zeros(c, x.batch * oh * ow);
        let mut argmax = vec![0usize; c * x.batch * oh * ow];
        for ch in 0..c {
            let src = x.mat.row(ch);
            let dst = y.row_mut(ch);
            for b in 0..x.batch {
                for orow in 0..oh {
                    for ocol in 0..ow {
                        let mut best = f32::NEG_INFINITY;
                        let mut best_idx = 0usize;
                        for dr in 0..k {
                            for dc in 0..k {
                                let idx = b * x.h * x.w + (orow * k + dr) * x.w + ocol * k + dc;
                                if src[idx] > best {
                                    best = src[idx];
                                    best_idx = idx;
                                }
                            }
                        }
                        let o = b * oh * ow + orow * ow + ocol;
                        dst[o] = best;
                        argmax[ch * x.batch * oh * ow + o] = best_idx;
                    }
                }
            }
        }
        self.cache = Some((argmax, x.h, x.w, x.batch));
        Act::from_image(y, x.batch, oh, ow)
    }

    pub fn backward(&mut self, dy: &Act) -> Act {
        let (argmax, h, w, batch) = self.cache.as_ref().expect("MaxPool backward");
        let c = dy.channels();
        let os = dy.h * dy.w;
        let mut dx = Mat::zeros(c, batch * h * w);
        for ch in 0..c {
            let src = dy.mat.row(ch);
            for o in 0..batch * os {
                dx.row_mut(ch)[argmax[ch * batch * os + o]] += src[o];
            }
        }
        Act::from_image(dx, *batch, *h, *w)
    }
}

/// Global average pooling to 1×1.
#[derive(Clone, Debug, Default)]
pub struct GlobalAvgPool {
    cache: Option<(usize, usize, usize)>,
}

impl GlobalAvgPool {
    pub fn new() -> GlobalAvgPool {
        GlobalAvgPool { cache: None }
    }

    pub fn forward(&mut self, x: &Act, _train: bool) -> Act {
        let s = x.spatial();
        let mut y = Mat::zeros(x.channels(), x.batch);
        for ch in 0..x.channels() {
            let src = x.mat.row(ch);
            let dst = y.row_mut(ch);
            for b in 0..x.batch {
                dst[b] = src[b * s..(b + 1) * s].iter().sum::<f32>() / s as f32;
            }
        }
        self.cache = Some((x.h, x.w, x.batch));
        Act::from_image(y, x.batch, 1, 1)
    }

    pub fn backward(&mut self, dy: &Act) -> Act {
        let (h, w, batch) = self.cache.expect("GlobalAvgPool backward");
        let s = h * w;
        let inv = 1.0 / s as f32;
        let mut dx = Mat::zeros(dy.channels(), batch * s);
        for ch in 0..dy.channels() {
            let src = dy.mat.row(ch);
            let dst = dx.row_mut(ch);
            for b in 0..batch {
                let g = src[b] * inv;
                for v in &mut dst[b * s..(b + 1) * s] {
                    *v = g;
                }
            }
        }
        Act::from_image(dx, batch, h, w)
    }
}

/// Flatten image activations into feature activations.
#[derive(Clone, Debug, Default)]
pub struct Flatten {
    cache: Option<(usize, usize, usize)>, // (c, h, w)
}

impl Flatten {
    pub fn new() -> Flatten {
        Flatten { cache: None }
    }

    pub fn forward(&mut self, x: &Act, _train: bool) -> Act {
        self.cache = Some((x.channels(), x.h, x.w));
        x.flatten()
    }

    pub fn backward(&mut self, dy: &Act) -> Act {
        let (c, h, w) = self.cache.expect("Flatten backward");
        dy.unflatten(c, h, w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::engine::EngineKind;
    use crate::nn::model::BackwardCtx;
    use crate::util::prop::assert_close;
    use crate::util::Rng;

    fn fd_check_scalar<F: FnMut(&Act) -> f32>(
        x: &Act,
        dx: &Act,
        mut f: F,
        eps: f32,
        tol: f32,
    ) {
        // Directional finite-difference against analytic dx for a handful of
        // coordinates.
        let n = x.mat.data.len();
        for probe in [0usize, n / 3, n / 2, n - 1] {
            let mut xp = x.clone();
            xp.mat.data[probe] += eps;
            let mut xm = x.clone();
            xm.mat.data[probe] -= eps;
            let fd = (f(&xp) - f(&xm)) / (2.0 * eps);
            let an = dx.mat.data[probe];
            assert!((fd - an).abs() < tol * (1.0 + fd.abs()), "probe {probe}: fd {fd} vs {an}");
        }
    }

    #[test]
    fn relu_backward_masks() {
        let mut r = Relu::new();
        let x = Act::from_features(Mat::from_slice(2, 2, &[1.0, -2.0, 0.5, -0.1]), 2);
        let y = r.forward(&x, true);
        assert_eq!(y.mat.data, vec![1.0, 0.0, 0.5, 0.0]);
        let dy = Act::from_features(Mat::from_slice(2, 2, &[1.0; 4]), 2);
        let dx = r.backward(&dy);
        assert_eq!(dx.mat.data, vec![1.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn linear_fd_gradcheck() {
        let mut rng = Rng::new(1);
        let mut lin = Linear::new(ProjEngine::new(EngineKind::Digital, 3, 4, &mut rng));
        let x = Act::from_features(Mat::randn(4, 2, 1.0, &mut rng), 2);
        // Loss = sum(y²)/2 ⇒ dy = y.
        let y = lin.forward(&x, true);
        let mut ctx = BackwardCtx::plain(Rng::new(2));
        let dx = lin.backward(&y, &mut ctx);
        let mut lin2 = lin.clone();
        fd_check_scalar(
            &x,
            &dx,
            |xx| {
                let yy = lin2.forward(xx, false);
                0.5 * yy.mat.data.iter().map(|v| v * v).sum::<f32>()
            },
            1e-3,
            1e-2,
        );
    }

    #[test]
    fn conv_fd_gradcheck() {
        let mut rng = Rng::new(3);
        let eng = ProjEngine::new(EngineKind::Digital, 3, 2 * 9, &mut rng);
        let mut conv = Conv2d::new(eng, 2, 3, 3, 1, 1);
        let x = Act::from_nchw(
            &(0..2 * 2 * 4 * 4).map(|_| rng.normal() as f32).collect::<Vec<_>>(),
            2,
            2,
            4,
            4,
        );
        let y = conv.forward(&x, true);
        assert_eq!((y.channels(), y.h, y.w), (3, 4, 4));
        let mut ctx = BackwardCtx::plain(Rng::new(4));
        let dx = conv.backward(&y, &mut ctx);
        let mut c2 = conv.clone();
        fd_check_scalar(
            &x,
            &dx,
            |xx| {
                let yy = c2.forward(xx, false);
                0.5 * yy.mat.data.iter().map(|v| v * v).sum::<f32>()
            },
            1e-3,
            2e-2,
        );
    }

    #[test]
    fn batchnorm_normalizes_and_gradchecks() {
        let mut rng = Rng::new(5);
        let mut bn = BatchNorm::new(3);
        let x = Act::from_features(Mat::randn(3, 50, 2.0, &mut rng), 50);
        let y = bn.forward(&x, true);
        for ch in 0..3 {
            let row = y.mat.row(ch);
            let m: f32 = row.iter().sum::<f32>() / 50.0;
            let v: f32 = row.iter().map(|a| (a - m) * (a - m)).sum::<f32>() / 50.0;
            assert!(m.abs() < 1e-4, "mean {m}");
            assert!((v - 1.0).abs() < 1e-3, "var {v}");
        }
        // Gradient check through the same loss.
        let dy = y.clone();
        let dx = bn.backward(&dy);
        let mut bn2 = bn.clone();
        fd_check_scalar(
            &x,
            &dx,
            |xx| {
                let yy = bn2.forward(xx, true);
                0.5 * yy.mat.data.iter().map(|v| v * v).sum::<f32>()
            },
            1e-2,
            5e-2,
        );
    }

    #[test]
    fn avgpool_roundtrip() {
        let mut p = AvgPool::new(2);
        let x = Act::from_nchw(&(0..16).map(|i| i as f32).collect::<Vec<_>>(), 1, 1, 4, 4);
        let y = p.forward(&x, true);
        assert_eq!((y.h, y.w), (2, 2));
        assert_eq!(y.mat.data[0], (0.0 + 1.0 + 4.0 + 5.0) / 4.0);
        let dy = Act::from_image(Mat::from_slice(1, 4, &[4.0; 4]), 1, 2, 2);
        let dx = p.backward(&dy);
        assert!(dx.mat.data.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn maxpool_routes_gradient() {
        let mut p = MaxPool::new(2);
        let x = Act::from_nchw(&[1.0, 2.0, 3.0, 9.0], 1, 1, 2, 2);
        let y = p.forward(&x, true);
        assert_eq!(y.mat.data, vec![9.0]);
        let dy = Act::from_image(Mat::from_slice(1, 1, &[5.0]), 1, 1, 1);
        let dx = p.backward(&dy);
        assert_eq!(dx.mat.data, vec![0.0, 0.0, 0.0, 5.0]);
    }

    #[test]
    fn global_avg_pool() {
        let mut p = GlobalAvgPool::new();
        let x = Act::from_nchw(&[1.0, 3.0, 5.0, 7.0], 1, 1, 2, 2);
        let y = p.forward(&x, true);
        assert_eq!(y.mat.data, vec![4.0]);
        let dx = p.backward(&Act::from_image(Mat::from_slice(1, 1, &[8.0]), 1, 1, 1));
        assert_eq!(dx.mat.data, vec![2.0; 4]);
    }

    #[test]
    fn flatten_roundtrip() {
        let mut rng = Rng::new(6);
        let mut f = Flatten::new();
        let x = Act::from_nchw(
            &(0..2 * 3 * 2 * 2).map(|_| rng.normal() as f32).collect::<Vec<_>>(),
            2,
            3,
            2,
            2,
        );
        let y = f.forward(&x, true);
        assert_eq!((y.mat.rows, y.batch), (12, 2));
        let dx = f.backward(&y);
        assert_close(&dx.mat.data, &x.mat.data, 0.0, 0.0).unwrap();
    }
}
