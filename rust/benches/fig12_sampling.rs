//! Fig. 12: ablations of the three sampling levels on CNN-L / synthetic
//! FashionMNIST (the paper's ablation model).
//!
//!   (a) feedback strategies: uniform vs topk vs btopk — accuracy vs
//!       cumulative weight-gradient/feedback steps;
//!   (b) feature sampling: spatial (SS) vs column (CS) — accuracy vs steps
//!       (SS shows *no* step reduction, CS does);
//!   (c) data sparsity α_D sweep — accuracy vs training time reduction.

use l2ight::data::{DatasetKind, SynthSpec};
use l2ight::nn::{build_model, EngineKind, ModelArch};
use l2ight::photonics::NoiseModel;
use l2ight::sampling::{ColumnSampler, DataSampler, FeedbackSampler, FeedbackStrategy, Normalization};
use l2ight::stages::sl::{train, SlConfig, SlReport};
use l2ight::util::bench::Table;
use l2ight::util::{fmt_sig, Rng};

const WIDTH: f32 = 0.35;

fn run(cfg: &SlConfig, datasets: &(l2ight::data::Dataset, l2ight::data::Dataset)) -> SlReport {
    let mut rng = Rng::new(0x12a);
    let kind = EngineKind::Photonic { k: 9, noise: NoiseModel::quant_only(8) };
    let mut model = build_model(ModelArch::CnnL, kind, 10, WIDTH, &mut rng);
    train(&mut model, &datasets.0, &datasets.1, cfg)
}

fn main() {
    println!("== Fig. 12: multi-level sampling ablations (CNN-L, synthetic Fashion) ==");
    let datasets = SynthSpec::new(DatasetKind::FashionLike, 256, 128).generate();
    let base = SlConfig { epochs: 6, batch: 32, eval_every: 1, seed: 0xf12, ..SlConfig::default() };

    // (a) feedback strategies at matched keep 0.5.
    let mut ta = Table::new(&["strategy", "best acc", "fbk energy", "fbk steps", "critical-path balance"]);
    for (name, strat) in [
        ("dense", None),
        ("uniform", Some(FeedbackStrategy::Uniform)),
        ("topk", Some(FeedbackStrategy::TopK)),
        ("btopk", Some(FeedbackStrategy::BTopK)),
    ] {
        let cfg = SlConfig {
            feedback: strat
                .map(|s| FeedbackSampler::new(s, 0.5, Normalization::Exp)),
            ..base.clone()
        };
        let r = run(&cfg, &datasets);
        ta.row(&[
            name.to_string(),
            format!("{:.3}", r.best_test_acc),
            fmt_sig(r.cost.fbk_energy, 3),
            fmt_sig(r.cost.fbk_steps, 3),
            if name == "topk" { "greedy (imbalanced)".into() } else { "-".to_string() },
        ]);
    }
    ta.print("Fig 12(a) — feedback sampling strategies (keep 0.5)");

    // (b) SS vs CS at matched keep 0.5 — the step-reduction contrast.
    let mut tb = Table::new(&["feature sampling", "best acc", "wgrad energy", "wgrad steps"]);
    for (name, feat) in [
        ("none", ColumnSampler::OFF),
        ("spatial (SS)", ColumnSampler::spatial(0.5, true)),
        ("column (CS)", ColumnSampler::column(0.5)),
    ] {
        let cfg = SlConfig { feature: feat, ..base.clone() };
        let r = run(&cfg, &datasets);
        tb.row(&[
            name.to_string(),
            format!("{:.3}", r.best_test_acc),
            fmt_sig(r.cost.wgrad_energy, 3),
            fmt_sig(r.cost.wgrad_steps, 3),
        ]);
    }
    tb.print("Fig 12(b) — SS vs CS (keep 0.5)");
    println!("(paper shape: SS cuts storage but NOT PTC steps; CS cuts both)");

    // (c) data sparsity sweep.
    let mut tc = Table::new(&["alpha_D", "best acc", "total energy", "total steps", "iters run"]);
    for ad in [0.0f32, 0.2, 0.5, 0.8] {
        let cfg = SlConfig { data: DataSampler::new(ad), ..base.clone() };
        let r = run(&cfg, &datasets);
        let iters: usize = r.epochs.iter().map(|e| e.iters_run).sum();
        tc.row(&[
            format!("{ad:.1}"),
            format!("{:.3}", r.best_test_acc),
            fmt_sig(r.cost.total_energy(), 3),
            fmt_sig(r.cost.total_steps(), 3),
            iters.to_string(),
        ]);
    }
    tc.print("Fig 12(c) — SMD data sparsity sweep");
    println!("(paper shape: medium α_D trades little accuracy for proportional time cuts;");
    println!(" aggressive α_D works on easy tasks)");
}
