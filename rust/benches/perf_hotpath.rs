//! §Perf hot-path microbenchmarks (the before/after log lives in
//! `BENCH_perf_hotpath.json` — machine-readable, appended per run). Covers
//! the L3 bottlenecks DESIGN.md §8 names:
//!
//!   1. blocked mesh forward vs raw dense GEMM (the simulator floor),
//!   2. σ-gradient acquisition (Eq. 5 reciprocal passes),
//!   3. masked feedback,
//!   4. realization: phases → noisy unitaries (the ZOO inner-loop cost),
//!   5. feedback-mask generation (btopk heap-select),
//!   6. PJRT artifact call overhead (when artifacts are built).
//!
//! Plus the SIMD acceptance targets (ISSUE 5): a square-GEMM ladder
//! (256–1024) and the conv-forward path, fused packed-panel vs eager
//! im2col+GEMM — run once with `L2IGHT_SIMD=scalar` and once with the
//! default `auto` to get before/after medians in one JSON artifact (the
//! dispatch level is recorded per run).
//!
//! Env knobs:
//!   * `L2IGHT_THREADS`   — pool width (recorded in the JSON).
//!   * `L2IGHT_SIMD`      — kernel dispatch level (recorded in the JSON).
//!   * `L2IGHT_BENCH_QUICK=1` — 1-warmup smoke run for CI (tiny budget).
//!   * `L2IGHT_BENCH_JSON` — output path (default `BENCH_perf_hotpath.json`).
//!   * `L2IGHT_TUNE_PROFILE` / `L2IGHT_TUNE=auto` — autotuner profile used
//!     by GEMM dispatch (the blocking in effect is recorded per run).

use l2ight::linalg::{
    conv2d_forward_packed, im2col, matmul, matmul_into, simd, tune, Conv2dShape, Mat,
};
use l2ight::photonics::{NoiseModel, PtcMesh};
use l2ight::runtime::{default_artifact_dir, ArgValue, Runtime};
use l2ight::sampling::{FeedbackSampler, FeedbackStrategy, Normalization};
use l2ight::util::bench::{black_box, fmt_ns, git_rev, unix_time, Bencher, Table};
use l2ight::util::json::Json;
use l2ight::util::{pool, Rng};

fn main() {
    let quick = std::env::var("L2IGHT_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let threads = pool::global().threads();
    let level = simd::active();
    println!(
        "== perf: L3 hot paths (native simulator + PJRT overhead), {threads} threads, simd={} ==",
        level.name()
    );
    let mut bench = if quick { Bencher::new(20, 3) } else { Bencher::new(400, 20) };
    let mut t = Table::new(&["hot path", "median", "p10", "p90", "notes"]);

    let (n, k, b) = (72usize, 9usize, 64usize);
    let mut rng = Rng::new(0x9e4f);
    let w = Mat::randn(n, n, 0.5, &mut rng);
    let x = Mat::randn(n, b, 1.0, &mut rng);
    let dy = Mat::randn(n, b, 1.0, &mut rng);

    // 1. dense GEMM floor.
    let g = bench.bench("dense gemm 72x72x64", || {
        black_box(matmul(&w, &x));
    });
    let last = |bench: &Bencher| {
        let m = bench.results().last().unwrap();
        (m.median_ns(), m.p10_ns(), m.p90_ns())
    };
    let (med, p10, p90) = last(&bench);
    t.row(&["dense gemm 72x72x64".into(), fmt_ns(med), fmt_ns(p10), fmt_ns(p90), "simulator floor".into()]);
    let gemm_ns = g;

    // 1b. square-GEMM ladder — the SIMD acceptance sizes (256–1024). Quick
    // mode keeps only 256 so the CI smoke stays cheap; the output buffer is
    // preallocated so the series measures kernels, not the allocator.
    let gemm_sizes: &[usize] = if quick { &[256] } else { &[256, 512, 1024] };
    for &s in gemm_sizes {
        let a = Mat::randn(s, s, 0.5, &mut rng);
        let b2 = Mat::randn(s, s, 0.5, &mut rng);
        let mut c = Mat::zeros(s, s);
        bench.bench(&format!("dense gemm {s}x{s}x{s}"), || {
            matmul_into(black_box(&a), black_box(&b2), &mut c);
        });
        let (med, p10, p90) = last(&bench);
        t.row(&[
            format!("dense gemm {s}x{s}x{s}"),
            fmt_ns(med),
            fmt_ns(p10),
            fmt_ns(p90),
            "SIMD acceptance".into(),
        ]);
    }

    // 1c. conv forward — fused packed-panel vs eager im2col+GEMM (the
    // §3.4.2 CNN hot loop; 32×144 weight over 8×16×16×16 activations).
    let csh = Conv2dShape {
        batch: 8,
        in_ch: 16,
        in_h: 16,
        in_w: 16,
        out_ch: 32,
        kernel: 3,
        stride: 1,
        padding: 1,
    };
    let cinput: Vec<f32> = (0..csh.batch * csh.in_ch * csh.in_h * csh.in_w)
        .map(|_| rng.normal() as f32)
        .collect();
    let wconv = Mat::randn(csh.out_ch, csh.patch_rows(), 0.5, &mut rng);
    let cf = bench.bench("conv fwd fused b8c16x16 k3", || {
        black_box(conv2d_forward_packed(&wconv, black_box(&cinput), &csh));
    });
    let (med, p10, p90) = last(&bench);
    t.row(&[
        "conv fwd fused b8c16x16 k3".into(),
        fmt_ns(med),
        fmt_ns(p10),
        fmt_ns(p90),
        "packed panels".into(),
    ]);
    let ce = bench.bench("conv fwd eager b8c16x16 k3", || {
        let patches = im2col(black_box(&cinput), &csh);
        black_box(matmul(&wconv, &patches));
    });
    let (med, p10, p90) = last(&bench);
    t.row(&[
        "conv fwd eager b8c16x16 k3".into(),
        fmt_ns(med),
        fmt_ns(p10),
        fmt_ns(p90),
        format!("{:.2}x fused", ce / cf),
    ]);

    // 2. mesh forward (realization cached — the SL steady state).
    let mut mesh = PtcMesh::new(n, n, k, NoiseModel::PAPER, &mut rng);
    mesh.program_from_dense(&w);
    mesh.forward(&x); // warm the cache
    let f = bench.bench("mesh forward (cached)", || {
        black_box(mesh.forward(&x));
    });
    let (med, p10, p90) = last(&bench);
    t.row(&[
        "mesh forward (cached)".into(),
        fmt_ns(med),
        fmt_ns(p10),
        fmt_ns(p90),
        format!("{:.1}x gemm", f / gemm_ns),
    ]);

    // 3. mesh forward with realization (the ZOO-eval / noise-sim cost).
    let fr = bench.bench("mesh forward (realize)", || {
        mesh.invalidate();
        black_box(mesh.forward(&x));
    });
    let (med, p10, p90) = last(&bench);
    t.row(&[
        "mesh forward (realize)".into(),
        fmt_ns(med),
        fmt_ns(p10),
        fmt_ns(p90),
        format!("{:.1}x cached", fr / f),
    ]);

    // 4. σ-gradient.
    mesh.forward(&x); // re-warm
    bench.bench("sigma_grad", || {
        black_box(mesh.sigma_grad(&x, &dy, None, 1.0));
    });
    let (med, p10, p90) = last(&bench);
    t.row(&["sigma_grad (Eq.5)".into(), fmt_ns(med), fmt_ns(p10), fmt_ns(p90), String::new()]);

    // 5. feedback, dense and masked.
    bench.bench("feedback dense", || {
        black_box(mesh.feedback(&dy, None, 1.0));
    });
    let (med, p10, p90) = last(&bench);
    t.row(&["feedback dense".into(), fmt_ns(med), fmt_ns(p10), fmt_ns(p90), String::new()]);
    let sampler = FeedbackSampler::new(FeedbackStrategy::BTopK, 0.5, Normalization::Exp);
    let norms = mesh.block_norms_sq();
    let mask = sampler.draw(mesh.p, mesh.q, &norms, &mut rng);
    bench.bench("feedback masked 0.5", || {
        black_box(mesh.feedback(&dy, Some(&mask.keep), mask.scale));
    });
    let (med, p10, p90) = last(&bench);
    t.row(&["feedback masked 0.5".into(), fmt_ns(med), fmt_ns(p10), fmt_ns(p90), "~2x fewer products".into()]);

    // 6. mask generation (btopk select per layer per iteration).
    bench.bench("btopk mask draw 8x8", || {
        black_box(sampler.draw(8, 8, &vec![1.0; 64], &mut rng));
    });
    let (med, p10, p90) = last(&bench);
    t.row(&["btopk mask draw 8x8".into(), fmt_ns(med), fmt_ns(p10), fmt_ns(p90), "per layer per iter".into()]);

    // 7. single-PTC realization (the ZOO inner-loop unit cost).
    let mut ptc = l2ight::photonics::ptc::Ptc::new(9, NoiseModel::PAPER, &mut rng);
    bench.bench("ptc realize 9x9", || {
        ptc.set_phase(l2ight::photonics::ptc::Which::U, 0, black_box(0.1));
        black_box(ptc.realized_u());
    });
    let (med, p10, p90) = last(&bench);
    t.row(&["ptc realize 9x9 (1 phase poke)".into(), fmt_ns(med), fmt_ns(p10), fmt_ns(p90), "ZOO eval unit".into()]);

    // 8. PJRT call overhead (artifact path).
    if !default_artifact_dir().join("manifest.json").exists() {
        t.row(&["pjrt call".into(), "-".into(), "-".into(), "-".into(), "run `make artifacts`".into()]);
    } else if quick {
        t.row(&["pjrt call".into(), "-".into(), "-".into(), "-".into(), "skipped (quick mode)".into()]);
    } else {
        match Runtime::new(&default_artifact_dir()) {
            Ok(mut rt) => {
                let name = "ptc_forward_p2_q2_k9_b18";
                let spec = rt.manifest().find(name).unwrap().clone();
                let args_data: Vec<Vec<f32>> =
                    spec.args.iter().map(|a| vec![0.1f32; a.numel()]).collect();
                rt.ensure_compiled(name).unwrap();
                bench.bench("pjrt ptc_forward call", || {
                    let args: Vec<ArgValue> = args_data.iter().map(|d| ArgValue::F32(d)).collect();
                    black_box(rt.call1_f32(name, &args).unwrap());
                });
                let (med, p10, p90) = last(&bench);
                t.row(&["pjrt ptc_forward call".into(), fmt_ns(med), fmt_ns(p10), fmt_ns(p90), "2x2 blocks k=9 b=18".into()]);
            }
            Err(e) => {
                t.row(&["pjrt call".into(), "-".into(), "-".into(), "-".into(), format!("{e:#}")]);
            }
        }
    }

    t.print("perf — hot-path medians");

    let json_path = std::env::var("L2IGHT_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_perf_hotpath.json".to_string());
    match emit_json(&bench, threads, level.name(), quick, &json_path) {
        Ok(()) => println!("\nwrote {json_path}"),
        Err(e) => eprintln!("WARN: could not write {json_path}: {e}"),
    }
}

/// Append this run (median/p10/p90 per hot path, thread count, SIMD level,
/// git rev) to the machine-readable perf log, keeping the last 50 runs so
/// the perf trajectory is diffable across commits — and so a scalar run
/// followed by an auto run gives before/after medians in one artifact.
fn emit_json(
    bench: &Bencher,
    threads: usize,
    simd: &str,
    quick: bool,
    path: &str,
) -> std::io::Result<()> {
    let mut runs: Vec<Json> = std::fs::read_to_string(path)
        .ok()
        .and_then(|src| Json::parse(&src).ok())
        .and_then(|root| root.get("runs").and_then(|r| r.as_arr()).map(|r| r.to_vec()))
        .unwrap_or_default();

    let mut run = Json::obj();
    run.set("git_rev", Json::Str(git_rev()));
    run.set("threads", Json::Num(threads as f64));
    run.set("simd", Json::Str(simd.to_string()));
    run.set("quick", Json::Bool(quick));
    run.set("unix_time", Json::Num(unix_time()));
    // The blocking the dispatch layer used for this run — default grid or a
    // tuned per-host profile — so before/after medians are attributable.
    let level = simd::active();
    let blk = tune::gemm_blocking(level);
    let mut blocking = Json::obj();
    blocking.set("mc", Json::Num(blk.mc as f64));
    blocking.set("kc", Json::Num(blk.kc as f64));
    blocking.set("nc", Json::Num(blk.nc as f64));
    blocking.set("panel_cols", Json::Num(tune::panel_cols_for(level) as f64));
    blocking.set("tuned", Json::Bool(tune::installed().level(level).is_some()));
    run.set("blocking", blocking);
    let mut paths = Vec::new();
    for m in bench.results() {
        let mut o = Json::obj();
        o.set("name", Json::Str(m.name.clone()));
        o.set("median_ns", Json::Num(m.median_ns()));
        o.set("p10_ns", Json::Num(m.p10_ns()));
        o.set("p90_ns", Json::Num(m.p90_ns()));
        o.set("samples", Json::Num(m.samples_ns.len() as f64));
        paths.push(o);
    }
    run.set("hot_paths", Json::Arr(paths));
    runs.push(run);
    let keep = runs.len().saturating_sub(50);
    let runs = runs.split_off(keep);

    let mut root = Json::obj();
    root.set("bench", Json::Str("perf_hotpath".to_string()));
    root.set("schema", Json::Num(1.0));
    root.set("runs", Json::Arr(runs));
    std::fs::write(path, root.pretty() + "\n")
}
