//! §Perf hot-path microbenchmarks (the before/after log lives in
//! EXPERIMENTS.md §Perf). Covers the L3 bottlenecks DESIGN.md §8 names:
//!
//!   1. blocked mesh forward vs raw dense GEMM (the simulator floor),
//!   2. σ-gradient acquisition (Eq. 5 reciprocal passes),
//!   3. masked feedback,
//!   4. realization: phases → noisy unitaries (the ZOO inner-loop cost),
//!   5. feedback-mask generation (btopk heap-select),
//!   6. PJRT artifact call overhead (when artifacts are built).

use l2ight::linalg::{matmul, Mat};
use l2ight::photonics::{NoiseModel, PtcMesh};
use l2ight::runtime::{default_artifact_dir, ArgValue, Runtime};
use l2ight::sampling::{FeedbackSampler, FeedbackStrategy, Normalization};
use l2ight::util::bench::{black_box, fmt_ns, Bencher, Table};
use l2ight::util::Rng;

fn main() {
    println!("== perf: L3 hot paths (native simulator + PJRT overhead) ==");
    let mut bench = Bencher::new(400, 20);
    let mut t = Table::new(&["hot path", "median", "p10", "p90", "notes"]);

    let (n, k, b) = (72usize, 9usize, 64usize);
    let mut rng = Rng::new(0x9e4f);
    let w = Mat::randn(n, n, 0.5, &mut rng);
    let x = Mat::randn(n, b, 1.0, &mut rng);
    let dy = Mat::randn(n, b, 1.0, &mut rng);

    // 1. dense GEMM floor.
    let g = bench.bench("dense gemm 72x72x64", || {
        black_box(matmul(&w, &x));
    });
    let last = |bench: &Bencher| {
        let m = bench.results().last().unwrap();
        (m.median_ns(), m.p10_ns(), m.p90_ns())
    };
    let (med, p10, p90) = last(&bench);
    t.row(&["dense gemm 72x72x64".into(), fmt_ns(med), fmt_ns(p10), fmt_ns(p90), "simulator floor".into()]);
    let gemm_ns = g;

    // 2. mesh forward (realization cached — the SL steady state).
    let mut mesh = PtcMesh::new(n, n, k, NoiseModel::PAPER, &mut rng);
    mesh.program_from_dense(&w);
    mesh.forward(&x); // warm the cache
    let f = bench.bench("mesh forward (cached)", || {
        black_box(mesh.forward(&x));
    });
    let (med, p10, p90) = last(&bench);
    t.row(&[
        "mesh forward (cached)".into(),
        fmt_ns(med),
        fmt_ns(p10),
        fmt_ns(p90),
        format!("{:.1}x gemm", f / gemm_ns),
    ]);

    // 3. mesh forward with realization (the ZOO-eval / noise-sim cost).
    let fr = bench.bench("mesh forward (realize)", || {
        mesh.invalidate();
        black_box(mesh.forward(&x));
    });
    let (med, p10, p90) = last(&bench);
    t.row(&[
        "mesh forward (realize)".into(),
        fmt_ns(med),
        fmt_ns(p10),
        fmt_ns(p90),
        format!("{:.1}x cached", fr / f),
    ]);

    // 4. σ-gradient.
    mesh.forward(&x); // re-warm
    bench.bench("sigma_grad", || {
        black_box(mesh.sigma_grad(&x, &dy, None, 1.0));
    });
    let (med, p10, p90) = last(&bench);
    t.row(&["sigma_grad (Eq.5)".into(), fmt_ns(med), fmt_ns(p10), fmt_ns(p90), String::new()]);

    // 5. feedback, dense and masked.
    bench.bench("feedback dense", || {
        black_box(mesh.feedback(&dy, None, 1.0));
    });
    let (med, p10, p90) = last(&bench);
    t.row(&["feedback dense".into(), fmt_ns(med), fmt_ns(p10), fmt_ns(p90), String::new()]);
    let sampler = FeedbackSampler::new(FeedbackStrategy::BTopK, 0.5, Normalization::Exp);
    let norms = mesh.block_norms_sq();
    let mask = sampler.draw(mesh.p, mesh.q, &norms, &mut rng);
    bench.bench("feedback masked 0.5", || {
        black_box(mesh.feedback(&dy, Some(&mask.keep), mask.scale));
    });
    let (med, p10, p90) = last(&bench);
    t.row(&["feedback masked 0.5".into(), fmt_ns(med), fmt_ns(p10), fmt_ns(p90), "~2x fewer products".into()]);

    // 6. mask generation (btopk select per layer per iteration).
    bench.bench("btopk mask draw 8x8", || {
        black_box(sampler.draw(8, 8, &vec![1.0; 64], &mut rng));
    });
    let (med, p10, p90) = last(&bench);
    t.row(&["btopk mask draw 8x8".into(), fmt_ns(med), fmt_ns(p10), fmt_ns(p90), "per layer per iter".into()]);

    // 7. single-PTC realization (the ZOO inner-loop unit cost).
    let mut ptc = l2ight::photonics::ptc::Ptc::new(9, NoiseModel::PAPER, &mut rng);
    bench.bench("ptc realize 9x9", || {
        ptc.set_phase(l2ight::photonics::ptc::Which::U, 0, black_box(0.1));
        black_box(ptc.realized_u());
    });
    let (med, p10, p90) = last(&bench);
    t.row(&["ptc realize 9x9 (1 phase poke)".into(), fmt_ns(med), fmt_ns(p10), fmt_ns(p90), "ZOO eval unit".into()]);

    // 8. PJRT call overhead (artifact path).
    if default_artifact_dir().join("manifest.json").exists() {
        let mut rt = Runtime::new(&default_artifact_dir()).expect("runtime");
        let name = "ptc_forward_p2_q2_k9_b18";
        let spec = rt.manifest().find(name).unwrap().clone();
        let args_data: Vec<Vec<f32>> =
            spec.args.iter().map(|a| vec![0.1f32; a.numel()]).collect();
        rt.ensure_compiled(name).unwrap();
        bench.bench("pjrt ptc_forward call", || {
            let args: Vec<ArgValue> = args_data.iter().map(|d| ArgValue::F32(d)).collect();
            black_box(rt.call1_f32(name, &args).unwrap());
        });
        let (med, p10, p90) = last(&bench);
        t.row(&["pjrt ptc_forward call".into(), fmt_ns(med), fmt_ns(p10), fmt_ns(p90), "2x2 blocks k=9 b=18".into()]);
    } else {
        t.row(&["pjrt call".into(), "-".into(), "-".into(), "-".into(), "run `make artifacts`".into()]);
    }

    t.print("perf — hot-path medians");
}
