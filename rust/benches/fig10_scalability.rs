//! Fig. 10 + Table 1 numbers: scalability of on-chip training protocols.
//!
//! Measured part: FLOPS [20], MixedTrn [17], and L2ight on the same
//! photonic models of increasing size (MLP width sweep) under the paper's
//! noise — ZO protocols degrade as the phase-space dimension grows while
//! L2ight (map + first-order subspace) keeps accuracy.
//!
//! Projected part: hardware cost to train the paper's large models
//! (VGG-8 / ResNet-18 scale) from the Appendix-G analytic model — running
//! a 10M-parameter ONN per protocol is exactly what the ZO baselines
//! *cannot* do, which is the point of the figure.

use l2ight::coordinator::{run_job, JobConfig, MetricSink, Protocol};
use l2ight::data::DatasetKind;
use l2ight::nn::ModelArch;
use l2ight::photonics::NoiseModel;
use l2ight::profiler::{training_cost, LayerCost, SparsityConfig};
use l2ight::util::bench::Table;
use l2ight::util::fmt_sig;

fn main() {
    println!("== Fig. 10: protocol scalability (measured, MLP width sweep) ==");
    let mut t = Table::new(&[
        "width",
        "#params(dense)",
        "protocol",
        "best acc",
        "PTC energy",
        "queries",
    ]);
    for width in [0.5f32, 1.0, 2.0] {
        for protocol in [Protocol::Flops, Protocol::MixedTrn, Protocol::L2ight] {
            let cfg = JobConfig {
                arch: ModelArch::MlpVowel,
                dataset: DatasetKind::VowelLike,
                protocol,
                k: 4,
                noise: NoiseModel::PAPER,
                width,
                n_train: 256,
                n_test: 128,
                pretrain_epochs: 10,
                epochs: if protocol == Protocol::L2ight { 5 } else { 8 },
                batch: 32,
                alpha_w: 0.6,
                alpha_c: 1.0,
                alpha_d: 0.0,
                zo_budget: 0.2,
                seed: 17,
                robustness: None,
                sharding: None,
                variation: None,
            };
            let mut sink = MetricSink::memory();
            let s = run_job(&cfg, &mut sink);
            t.row(&[
                format!("{width:.1}"),
                s.total_params.to_string(),
                protocol.name().to_string(),
                format!("{:.3}", s.best_acc),
                fmt_sig(s.cost.total_energy(), 3),
                s.zo_queries.to_string(),
            ]);
        }
    }
    t.print("Fig 10 (measured) — accuracy & cost vs model size per protocol");

    println!("\n== Fig. 10 (projected): training cost at paper scale (Appendix-G model) ==");
    // Layer inventories of the paper's models at k=9 (full width, CIFAR).
    let vgg8: Vec<LayerCost> = vec![
        LayerCost::conv2d(64, 3, 3, 32, 32, 1, 1, 9),
        LayerCost::conv2d(64, 64, 3, 32, 32, 1, 1, 9),
        LayerCost::conv2d(128, 64, 3, 16, 16, 1, 1, 9),
        LayerCost::conv2d(128, 128, 3, 16, 16, 1, 1, 9),
        LayerCost::conv2d(256, 128, 3, 8, 8, 1, 1, 9),
        LayerCost::conv2d(256, 256, 3, 8, 8, 1, 1, 9),
        LayerCost::linear(512, 256 * 4 * 4, 9),
        LayerCost::linear(10, 512, 9),
    ];
    let resnet18: Vec<LayerCost> = {
        let mut v = vec![LayerCost::conv2d(64, 3, 3, 32, 32, 1, 1, 9)];
        let stages: [(usize, usize, usize); 4] =
            [(64, 32, 2), (128, 16, 2), (256, 8, 2), (512, 4, 2)];
        let mut cin = 64;
        for (cout, side, blocks) in stages {
            for b in 0..blocks {
                let s_in = if b == 0 && cin != cout { side * 2 } else { side };
                v.push(LayerCost::conv2d(cout, cin, 3, s_in, s_in, if b == 0 && cin != cout { 2 } else { 1 }, 1, 9));
                v.push(LayerCost::conv2d(cout, cout, 3, side, side, 1, 1, 9));
                cin = cout;
            }
        }
        v.push(LayerCost::linear(10, 512, 9));
        v
    };

    let mut t2 = Table::new(&[
        "model",
        "#params",
        "#phases",
        "protocol",
        "energy / epoch",
        "feasible?",
    ]);
    for (name, layers) in [("VGG-8", &vgg8), ("ResNet-18", &resnet18)] {
        let params: usize = layers.iter().map(|l| l.params()).sum();
        let phases: usize = layers.iter().map(|l| l.phases()).sum();
        let iters = 50_000 / 32; // CIFAR-10 epoch at batch 32
        // L2ight: one fwd+bwd per iteration (first-order, Appendix G).
        let ours = training_cost(layers, 32, iters, 1, SparsityConfig {
            alpha_w: 0.6,
            alpha_c: 0.6,
            alpha_d: 0.5,
        });
        // FLOPS: 2·grad_samples+1 forward queries per iteration over the
        // *whole phase space*; per-query cost is a full forward.
        let fwd = l2ight::profiler::forward_cost(layers, 32);
        let flops_epoch = fwd.total_energy() * (2.0 * 5.0 + 1.0) * iters as f64;
        // MixedTrn: ~3 queries per active phase coordinate per iteration at
        // 4% activity — dominated by the phase count.
        let mixed_epoch = fwd.total_energy() * (0.04 * phases as f64) * iters as f64;
        t2.row(&[
            name.into(),
            fmt_sig(params as f64, 3),
            fmt_sig(phases as f64, 3),
            "L2ight".into(),
            fmt_sig(ours.total_energy(), 3),
            "yes (first-order)".into(),
        ]);
        t2.row(&[
            name.into(),
            fmt_sig(params as f64, 3),
            fmt_sig(phases as f64, 3),
            "FLOPS".into(),
            fmt_sig(flops_epoch, 3),
            format!("{}x L2ight", fmt_sig(flops_epoch / ours.total_energy(), 2)),
        ]);
        t2.row(&[
            name.into(),
            fmt_sig(params as f64, 3),
            fmt_sig(phases as f64, 3),
            "MixedTrn".into(),
            fmt_sig(mixed_epoch, 3),
            format!("{}x L2ight", fmt_sig(mixed_epoch / ours.total_energy(), 2)),
        ]);
    }
    t2.print("Fig 10 (projected) — per-epoch PTC energy at paper scale, k=9");
    println!("\n(paper shape: prior ZO protocols handle ~100-2500 params; L2ight reaches ~10M —");
    println!(" >1000x scalability — because ZO query counts scale with phase-space dimension)");
}
