//! Fig. 8: σ-gradient approximation fidelity (average angular similarity
//! and normalized distance) —
//!   (a) feedback sampling: btopk across sparsity levels,
//!   (b) normalization variants (none / exp / var) at fixed sparsity,
//!   (c) spatial sampling (SS) vs column sampling (CS) across sparsity,
//!   (d) normalization under feature sampling.
//!
//! Paper shape: similarity degrades gracefully with sparsity; exp
//! normalization gives the best-aligned feedback gradients; CS preserves
//! more information than SS at matched sparsity.

use l2ight::data::{DatasetKind, SynthSpec};
use l2ight::nn::{build_model, EngineKind, ModelArch};
use l2ight::photonics::NoiseModel;
use l2ight::sampling::{
    grad_fidelity, ColumnSampler, FeedbackSampler, FeedbackStrategy, Normalization,
};
use l2ight::util::bench::Table;
use l2ight::util::Rng;

fn main() {
    println!("== Fig. 8: gradient approximation fidelity (CNN-L-style, photonic) ==");
    let mut rng = Rng::new(8);
    let kind = EngineKind::Photonic { k: 9, noise: NoiseModel::IDEAL };
    // CNN-L on a Fashion-shaped task (the paper's Fig. 8 model), slim width.
    let mut model = build_model(ModelArch::CnnL, kind, 10, 0.5, &mut rng);
    let (ds, _) = SynthSpec::new(DatasetKind::FashionLike, 64, 8).generate();
    let idx: Vec<usize> = (0..16).collect();
    let draws = 5;

    // (a) feedback sparsity sweep with btopk + exp.
    let mut ta = Table::new(&["keep α_W", "angular sim", "norm dist"]);
    for keep in [0.9f32, 0.7, 0.5, 0.3] {
        let fs = FeedbackSampler::new(FeedbackStrategy::BTopK, 1.0 - keep, Normalization::Exp);
        let (sim, dist) =
            grad_fidelity(&mut model, &ds, &idx, Some(fs), ColumnSampler::OFF, draws, 42);
        ta.row(&[format!("{keep:.1}"), format!("{sim:.4}"), format!("{dist:.4}")]);
    }
    ta.print("Fig 8(a) — feedback sparsity (btopk, exp norm)");

    // (b) normalization comparison at α_W = 0.5.
    let mut tb = Table::new(&["normalization", "angular sim", "norm dist"]);
    for (name, norm) in [
        ("none", Normalization::None),
        ("exp", Normalization::Exp),
        ("var", Normalization::Var),
    ] {
        let fs = FeedbackSampler::new(FeedbackStrategy::BTopK, 0.5, norm);
        let (sim, dist) =
            grad_fidelity(&mut model, &ds, &idx, Some(fs), ColumnSampler::OFF, draws, 43);
        tb.row(&[name.to_string(), format!("{sim:.4}"), format!("{dist:.4}")]);
    }
    tb.print("Fig 8(b) — normalization (btopk, keep 0.5)");

    // (c) SS vs CS sweep.
    let mut tc = Table::new(&["keep α_C", "CS angular sim", "SS angular sim", "CS dist", "SS dist"]);
    for keep in [0.9f32, 0.7, 0.5, 0.3] {
        let cs = ColumnSampler::column(1.0 - keep);
        let ss = ColumnSampler::spatial(1.0 - keep, true);
        let (sim_cs, dist_cs) = grad_fidelity(&mut model, &ds, &idx, None, cs, draws, 44);
        let (sim_ss, dist_ss) = grad_fidelity(&mut model, &ds, &idx, None, ss, draws, 44);
        tc.row(&[
            format!("{keep:.1}"),
            format!("{sim_cs:.4}"),
            format!("{sim_ss:.4}"),
            format!("{dist_cs:.4}"),
            format!("{dist_ss:.4}"),
        ]);
    }
    tc.print("Fig 8(c) — column (CS) vs spatial (SS) feature sampling");

    // (d) normalization under CS at keep 0.5.
    let mut td = Table::new(&["normalization", "angular sim", "norm dist"]);
    for (name, rescale) in [("none", false), ("exp", true)] {
        let cs = ColumnSampler { rescale, ..ColumnSampler::column(0.5) };
        let (sim, dist) = grad_fidelity(&mut model, &ds, &idx, None, cs, draws, 45);
        td.row(&[name.to_string(), format!("{sim:.4}"), format!("{dist:.4}")]);
    }
    td.print("Fig 8(d) — normalization under column sampling (keep 0.5)");

    println!("\n(paper shape: similarity falls smoothly with sparsity; exp is unbiased and");
    println!(" best-aligned; CS ≥ SS at matched sparsity because pixels survive in other columns)");
}
