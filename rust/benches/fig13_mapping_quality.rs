//! Fig. 13: impact of calibration/mapping quality on subspace learning
//! (VGG-8-class experiment scaled to CNN-S / synthetic MNIST).
//!
//! Curves: SL starting from (1) random unitaries (train from scratch),
//! (2) a roughly-mapped model (low ZO budget), (3) a well-mapped model,
//! and (4) a well-mapped model with non-ideal Ĩ (acc-NI — IC left with
//! residual MSE ≈ 0.013 worth of gradient noise).
//!
//! Paper shape: mapping quality sets the starting point but subspace
//! learning compensates for moderate suboptimality; non-ideal Ĩ costs
//! almost nothing (the sign flips cancel in Eq. 5).

use l2ight::data::{DatasetKind, SynthSpec};
use l2ight::nn::{build_model, EngineKind, ModelArch};
use l2ight::photonics::NoiseModel;
use l2ight::stages::pm::{copy_aux_params, map_model, PmConfig};
use l2ight::stages::sl::{train, OptKind, SlConfig};
use l2ight::util::bench::Table;
use l2ight::util::{fmt_sig, Rng};
use l2ight::zoo::ZoConfig;

fn main() {
    println!("== Fig. 13: mapping quality vs subspace-learning outcome (CNN-S) ==");
    let datasets = SynthSpec::new(DatasetKind::MnistLike, 384, 192).generate();
    let (train_set, test_set) = &datasets;

    // Pretrained digital source.
    let mut rng = Rng::new(13);
    let mut digital = build_model(ModelArch::CnnS, EngineKind::Digital, 10, 1.0, &mut rng);
    let pre_cfg = SlConfig {
        epochs: 8,
        batch: 32,
        opt: OptKind::Sgd { lr: 0.05, momentum: 0.9, weight_decay: 1e-4 },
        eval_every: 0,
        ..SlConfig::default()
    };
    let pre = train(&mut digital, train_set, test_set, &pre_cfg);
    println!("pretrained digital acc: {:.3}", pre.final_test_acc);

    let sl_cfg = SlConfig {
        epochs: 4,
        batch: 32,
        opt: OptKind::AdamW { lr: 5e-4, weight_decay: 1e-2 },
        eval_every: 1,
        seed: 0x13,
        ..SlConfig::default()
    };

    // Noise variants: quant-only = near-ideal Ĩ after mapping; PAPER =
    // includes the unknown-bias non-ideality (the acc-NI curve).
    let variants: &[(&str, Option<usize>, NoiseModel)] = &[
        ("scratch (random U,V*)", None, NoiseModel::quant_only(8)),
        ("rough map (ZO iters 4)", Some(4), NoiseModel::quant_only(8)),
        ("good map (ZO iters 40)", Some(40), NoiseModel::quant_only(8)),
        ("good map, non-ideal I~ (acc-NI)", Some(40), NoiseModel::PAPER),
    ];
    let mut t = Table::new(&["init", "mapped acc", "final acc", "epochs-to-final", "SL energy"]);
    let mut results = Vec::new();
    for (name, zo_iters, noise) in variants {
        let kind = EngineKind::Photonic { k: 9, noise: *noise };
        let mut chip = build_model(ModelArch::CnnS, kind, 10, 1.0, &mut Rng::new(99));
        let mapped_acc = match zo_iters {
            None => test_set.evaluate(&mut chip, 32),
            Some(iters) => {
                let cfg = PmConfig {
                    zo: ZoConfig { iters: *iters, ..PmConfig::default().zo },
                    alternations: 2,
                    ..PmConfig::default()
                };
                map_model(&mut chip, &mut digital, &cfg);
                copy_aux_params(&mut chip, &mut digital);
                test_set.evaluate(&mut chip, 32)
            }
        };
        chip.reset_mesh_stats();
        let r = train(&mut chip, train_set, test_set, &sl_cfg);
        results.push((name.to_string(), mapped_acc, r.final_test_acc));
        t.row(&[
            name.to_string(),
            format!("{mapped_acc:.3}"),
            format!("{:.3}", r.final_test_acc),
            sl_cfg.epochs.to_string(),
            fmt_sig(r.cost.total_energy(), 3),
        ]);
    }
    t.print("Fig 13 — SL outcome vs initialization quality");

    let find = |n: &str| results.iter().find(|(a, _, _)| a.contains(n)).unwrap();
    let scratch = find("scratch");
    let good = find("good map (ZO");
    let ni = find("non-ideal");
    println!(
        "\nmapped-init beats scratch at same budget: {} ({:.3} vs {:.3})",
        if good.2 >= scratch.2 { "OK (matches paper)" } else { "MISMATCH" },
        good.2,
        scratch.2
    );
    println!(
        "non-ideal I~ costs little:              {} ({:.3} vs {:.3})",
        if ni.2 >= good.2 - 0.08 { "OK (matches paper)" } else { "MISMATCH" },
        ni.2,
        good.2
    );
    println!("(paper shape: subspace optimization compensates moderate mapping error;\n gradient noise from non-ideal I~ (MSE≈0.013) barely hurts — signs cancel in Eq. 5)");
}
