//! Fig. 5: ZO optimizers on parallel mapping, and the effect of the final
//! optimal singular-value projection (OSP).
//!
//! Paper shape: ZTP and ZCD-B perform best; OSP gives a significant
//! normalized-matrix-distance drop and a 2-5% accuracy jump "for free".

use l2ight::data::{DatasetKind, SynthSpec};
use l2ight::nn::{build_model, EngineKind, ModelArch};
use l2ight::photonics::NoiseModel;
use l2ight::stages::pm::{copy_aux_params, map_model, PmConfig};
use l2ight::stages::sl::{train, OptKind, SlConfig};
use l2ight::util::bench::Table;
use l2ight::util::{fmt_sig, Rng};
use l2ight::zoo::{ZoConfig, ZoKind};

fn main() {
    println!("== Fig. 5: parallel mapping — ZO optimizer comparison + OSP ==");
    // Pretrain a digital MLP on the vowel task (the mapping target).
    let (train_set, test_set) =
        SynthSpec::new(DatasetKind::VowelLike, 512, 256).with_difficulty(0.8).generate();
    let mut rng = Rng::new(5);
    let mut digital = build_model(ModelArch::MlpVowel, EngineKind::Digital, 4, 1.0, &mut rng);
    let pre_cfg = SlConfig {
        epochs: 15,
        batch: 32,
        opt: OptKind::Sgd { lr: 0.1, momentum: 0.9, weight_decay: 1e-4 },
        eval_every: 0,
        ..SlConfig::default()
    };
    let pre = train(&mut digital, &train_set, &test_set, &pre_cfg);
    println!("pretrained digital accuracy: {:.3}", pre.final_test_acc);

    let noise = NoiseModel::PAPER;
    let mut t = Table::new(&[
        "optimizer",
        "rel err init",
        "rel err ZO",
        "rel err +OSP",
        "acc no-OSP",
        "acc +OSP",
        "queries",
    ]);
    let mut osp_gains = Vec::new();
    for kind in [ZoKind::Zgd, ZoKind::Zcd, ZoKind::Ztp] {
        let mut accs = [0.0f32; 2];
        let mut errs = [0.0f64; 3];
        let mut queries = 0u64;
        for (oi, osp) in [false, true].into_iter().enumerate() {
            let mut chip_rng = Rng::new(77);
            let chip_kind = EngineKind::Photonic { k: 4, noise };
            let mut chip = build_model(ModelArch::MlpVowel, chip_kind, 4, 1.0, &mut chip_rng);
            let cfg = PmConfig {
                optimizer: kind,
                zo: ZoConfig {
                    iters: 60,
                    step: 0.1,
                    decay: 0.99,
                    step_floor: 2e-3,
                    best_recording: true,
                },
                alternations: 3,
                osp,
                ..PmConfig::default()
            };
            let r = map_model(&mut chip, &mut digital, &cfg);
            copy_aux_params(&mut chip, &mut digital);
            accs[oi] = test_set.evaluate(&mut chip, 32);
            if osp {
                errs = [r.err_init, r.err_zo, r.err_osp];
                queries = r.queries;
            }
        }
        osp_gains.push((accs[1] - accs[0]) as f64);
        t.row(&[
            kind.name().to_string(),
            fmt_sig(errs[0], 3),
            fmt_sig(errs[1], 3),
            fmt_sig(errs[2], 3),
            format!("{:.3}", accs[0]),
            format!("{:.3}", accs[1]),
            queries.to_string(),
        ]);
    }
    t.print("Fig 5 — mapping fidelity and accuracy per ZO optimizer (MLP, paper noise)");
    let mean_gain = osp_gains.iter().sum::<f64>() / osp_gains.len() as f64;
    println!(
        "\nmean OSP accuracy jump: {:+.3} (paper: +2-5% almost for free)",
        mean_gain
    );
    println!("(paper shape: coordinate-wise ZCD/ZTP reach lower mapping error than ZGD;\n OSP drops the normalized matrix distance further at 3 PTC passes/block)");
}
