//! Fig. 14: in-situ transferability in the restricted subspace.
//!
//! (a)-style: transfer a CNN from a richer source task (20-class synthetic,
//! shared templates) to a 10-class target by training Σ only, vs. subspace
//! training from scratch. Reports final accuracy and steps-to-parity — the
//! paper's "1-2% higher accuracy, 3-5x fewer steps" claim shape.

use l2ight::data::{DatasetKind, SynthSpec};
use l2ight::nn::{build_model, EngineKind, ModelArch};
use l2ight::photonics::NoiseModel;
use l2ight::stages::pm::{copy_aux_params, map_model, PmConfig};
use l2ight::stages::sl::{train, OptKind, SlConfig};
use l2ight::util::bench::Table;
use l2ight::util::{fmt_sig, Rng};
use l2ight::zoo::ZoConfig;

fn main() {
    println!("== Fig. 14: subspace transfer (shared-template synthetic tasks, CNN-S) ==");
    let shared = 0x14_5eed;
    let (src_train, src_test) = SynthSpec::new(DatasetKind::MnistLike, 384, 192)
        .with_classes(20)
        .with_seeds(shared, 1)
        .generate();
    let (dst_train, dst_test) = SynthSpec::new(DatasetKind::MnistLike, 256, 192)
        .with_classes(10)
        .with_seeds(shared, 2)
        .generate();

    let mut rng = Rng::new(14);
    let mut digital = build_model(ModelArch::CnnS, EngineKind::Digital, 20, 1.0, &mut rng);
    let pre_cfg = SlConfig {
        epochs: 8,
        batch: 32,
        opt: OptKind::Sgd { lr: 0.05, momentum: 0.9, weight_decay: 1e-4 },
        eval_every: 0,
        ..SlConfig::default()
    };
    let pre = train(&mut digital, &src_train, &src_test, &pre_cfg);
    println!("source pretrain acc (20-class): {:.3}", pre.final_test_acc);

    let kind = EngineKind::Photonic { k: 9, noise: NoiseModel::quant_only(8) };
    let sl_cfg = SlConfig {
        epochs: 6,
        batch: 32,
        opt: OptKind::AdamW { lr: 5e-4, weight_decay: 1e-2 },
        eval_every: 1,
        seed: 0x14,
        ..SlConfig::default()
    };

    // Transfer: map source model, then Σ-train on the target.
    let mut transfer = build_model(ModelArch::CnnS, kind, 20, 1.0, &mut Rng::new(41));
    let pm_cfg = PmConfig {
        zo: ZoConfig { iters: 15, ..PmConfig::default().zo },
        alternations: 2,
        ..PmConfig::default()
    };
    map_model(&mut transfer, &mut digital, &pm_cfg);
    copy_aux_params(&mut transfer, &mut digital);
    let r_transfer = train(&mut transfer, &dst_train, &dst_test, &sl_cfg);

    // Scratch control (same budget, random unitaries, faster lr).
    let mut scratch = build_model(ModelArch::CnnS, kind, 20, 1.0, &mut Rng::new(43));
    let scratch_cfg =
        SlConfig { opt: OptKind::AdamW { lr: 2e-3, weight_decay: 1e-2 }, ..sl_cfg.clone() };
    let r_scratch = train(&mut scratch, &dst_train, &dst_test, &scratch_cfg);

    let mut t = Table::new(&["epoch", "transfer acc", "scratch acc", "cum steps (either)"]);
    let ct = r_transfer.acc_vs_steps();
    let cs = r_scratch.acc_vs_steps();
    for i in 0..ct.len().max(cs.len()) {
        t.row(&[
            i.to_string(),
            ct.get(i).map(|(_, a)| format!("{a:.3}")).unwrap_or_default(),
            cs.get(i).map(|(_, a)| format!("{a:.3}")).unwrap_or_default(),
            ct.get(i).or(cs.get(i)).map(|(s, _)| fmt_sig(*s, 3)).unwrap_or_default(),
        ]);
    }
    t.print("Fig 14 — transfer vs scratch, accuracy per epoch");

    let target = r_scratch.final_test_acc;
    let reach = |c: &[(f64, f32)]| c.iter().find(|(_, a)| *a >= target).map(|(s, _)| *s);
    println!(
        "\nfinal: transfer {:.3} vs scratch {:.3} ({})",
        r_transfer.final_test_acc,
        r_scratch.final_test_acc,
        if r_transfer.final_test_acc >= r_scratch.final_test_acc {
            "OK (matches paper: transfer higher)"
        } else {
            "MISMATCH"
        }
    );
    match (reach(&ct), reach(&cs)) {
        (Some(a), Some(b)) => println!(
            "steps to scratch-final acc: transfer {} vs scratch {} ({:.1}x fewer; paper 3-5x)",
            fmt_sig(a, 3),
            fmt_sig(b, 3),
            b / a.max(1e-9)
        ),
        _ => println!("transfer did not cross scratch-final accuracy in this budget"),
    }
}
