//! Serving latency/throughput under open-loop load: the deployment-facing
//! companion to `perf_hotpath` (kernel medians) — this measures what a
//! client of the batched serving engine actually sees: p50/p95/p99
//! admission→response latency, the batch-occupancy histogram, and (full
//! mode) the saturation throughput from a 1×/2×/4×/8× QPS ladder.
//!
//! Environment:
//!   * `L2IGHT_BENCH_QUICK=1` — the CI smoke preset (~2 s of load, no
//!     sweep; the serve-smoke leg asserts loop closure on the output).
//!   * `L2IGHT_SERVE_BENCH_JSON` — output path (default `BENCH_serve.json`).
//!   * `L2IGHT_THREADS` / `L2IGHT_SIMD` — compute pool + kernel dispatch,
//!     recorded per run like every other bench.
//!
//! Same history schema as `BENCH_perf_hotpath.json`: `{bench, schema,
//! runs: [...]}`, last 50 runs kept, each stamped with the git revision.

use std::path::Path;

use l2ight::serve::bench::{
    append_history, bench_run_json, print_summary, run_serve_bench, ServeBenchConfig,
};

fn main() {
    let quick = std::env::var("L2IGHT_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let cfg = if quick {
        ServeBenchConfig::quick()
    } else {
        ServeBenchConfig { sweep: true, ..ServeBenchConfig::default() }
    };
    println!(
        "serve_latency: {} requests at {:.0} qps (quick={quick}, sweep={})",
        cfg.requests, cfg.qps, cfg.sweep
    );

    let res = run_serve_bench(&cfg);
    print_summary(&cfg, &res);

    let json_path = std::env::var("L2IGHT_SERVE_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_serve.json".to_string());
    match append_history(Path::new(&json_path), bench_run_json(&cfg, &res)) {
        Ok(()) => println!("\nwrote {json_path}"),
        Err(e) => eprintln!("WARN: could not write {json_path}: {e}"),
    }
}
