//! Appendix F, Tables 3/4/5: why k = 9.
//!
//! T3 — noise-induced relative matrix error vs block size on a 256×256
//!      weight (paper: 20 runs; we use 5 — std is tiny).
//! T4 — identity-calibration solution quality (MSEᵁ+MSEⱽ)/2 vs block size
//!      (ZO curse of dimensionality).
//! T5 — subspace-learning accuracy vs block size (parameter-space shrinks
//!      as N²/k — too-big blocks lose trainability).

use l2ight::data::{DatasetKind, SynthSpec};
use l2ight::linalg::Mat;
use l2ight::nn::{build_model, EngineKind, ModelArch};
use l2ight::photonics::ptc::Ptc;
use l2ight::photonics::{NoiseModel, PtcMesh};
use l2ight::stages::ic::{calibrate_ptc, IcConfig};
use l2ight::stages::sl::{train, SlConfig};
use l2ight::util::bench::Table;
use l2ight::util::{fmt_sig, mean, std as stdev, Rng};
use l2ight::zoo::ZoConfig;

const SIZES: [usize; 6] = [8, 9, 12, 16, 24, 32];

fn table3() {
    println!("== Table 3: noise-induced relative matrix error vs block size (256x256) ==");
    let n = 256;
    let runs = 5;
    let mut t = Table::new(&["blk size", "rel err", "std", "paper rel err"]);
    let paper = [0.025, 0.032, 0.043, 0.061, 0.094, 0.126];
    for (i, &k) in SIZES.iter().enumerate() {
        let mut errs = Vec::new();
        for run in 0..runs {
            let mut rng = Rng::with_stream(0x7333, (k * 100 + run) as u64);
            let w = Mat::randn(n, n, 0.5, &mut rng);
            let mut mesh = PtcMesh::new(n, n, k, NoiseModel::PAPER_NO_BIAS, &mut rng);
            mesh.program_from_dense(&w);
            errs.push(mesh.rel_error(&w) as f64);
        }
        t.row(&[
            k.to_string(),
            fmt_sig(mean(&errs), 3),
            fmt_sig(stdev(&errs), 2),
            format!("{}", paper[i]),
        ]);
    }
    t.print("Table 3 — error accumulation grows with block size");
}

fn table4() {
    println!("\n== Table 4: IC solution quality vs block size ==");
    // Our MSE is per-entry (‖|U|−I‖²/k²), whose *uncalibrated* baseline
    // already shrinks like 1/k — so raw values are not comparable across k.
    // The dimensionality effect the paper's table demonstrates shows up in
    // the RESIDUAL FRACTION (final MSE / initial MSE): under a fixed query
    // budget, big blocks converge a much smaller fraction of the way.
    let mut t = Table::new(&["blk size", "init MSE", "final MSE", "residual frac", "paper MSE"]);
    let paper = [0.0135, 0.013, 0.03, 0.039, 0.04, 0.045];
    for (i, &k) in SIZES.iter().enumerate() {
        // Fixed total hardware-query budget across block sizes (the paper
        // fixes the calibration epochs).
        let dim = 2 * k * (k - 1) / 2;
        let iters = (60_000 / (2 * dim)).clamp(6, 600);
        let cfg = IcConfig {
            zo: ZoConfig { iters, step: 0.15, decay: 0.995, step_floor: 2e-3, best_recording: true },
            ..IcConfig::default()
        };
        let mut inits = Vec::new();
        let mut finals = Vec::new();
        for run in 0..2u64 {
            let mut rng = Rng::with_stream(0x7444, k as u64 * 10 + run);
            let mut ptc = Ptc::new(k, NoiseModel::PAPER, &mut rng);
            let (iu, iv) = ptc.identity_mse();
            inits.push((iu + iv) / 2.0);
            let mut zo_rng = Rng::with_stream(0x7445, k as u64 * 10 + run);
            let (_, (mu, mv)) = calibrate_ptc(&mut ptc, &cfg, &mut zo_rng);
            finals.push((mu + mv) / 2.0);
        }
        t.row(&[
            k.to_string(),
            fmt_sig(mean(&inits), 3),
            fmt_sig(mean(&finals), 3),
            format!("{:.2}", mean(&finals) / mean(&inits)),
            format!("{}", paper[i]),
        ]);
    }
    t.print("Table 4 — ZO calibration under a fixed query budget");
    println!("(paper shape: quality degrades with block size; here visible in the residual");
    println!(" fraction — our per-entry MSE normalization shrinks ~1/k, masking it in raw values)");
}

fn table5() {
    println!("\n== Table 5: subspace-learning accuracy vs block size (CNN on synthetic) ==");
    // The paper uses VGG8/CIFAR; we use CNN-L/synthetic-Fashion at reduced
    // width (same N²/k parameter-space scaling).
    let datasets = SynthSpec::new(DatasetKind::FashionLike, 256, 128).generate();
    let mut t = Table::new(&["blk size", "trainable Σ", "best acc", "paper acc"]);
    let paper = [84.26, 84.45, 83.36, 81.27, 80.68, 78.40];
    for (i, &k) in SIZES.iter().enumerate() {
        let kind = EngineKind::Photonic { k, noise: NoiseModel::quant_only(8) };
        let mut model = build_model(ModelArch::CnnL, kind, 10, 0.35, &mut Rng::new(55));
        let (trainable, _) = model.param_counts();
        let cfg = SlConfig { epochs: 5, batch: 32, eval_every: 0, seed: 0x7555, ..SlConfig::default() };
        let r = train(&mut model, &datasets.0, &datasets.1, &cfg);
        t.row(&[
            k.to_string(),
            trainable.to_string(),
            format!("{:.3}", r.best_test_acc),
            format!("{}", paper[i]),
        ]);
    }
    t.print("Table 5 — trainability shrinks with block size (fewer Σ per weight)");
    println!("(paper shape: k≈8-9 best; k≥16 loses accuracy to the smaller subspace)");
}

fn main() {
    table3();
    table4();
    table5();
}
