//! Fig. 1(b): ONN accuracy degradation under non-ideality combinations
//! (Q = 8-bit phase quantization, CT = crosstalk, DV = device γ-variation,
//! PB = unknown phase bias), evaluated by programming a pretrained model
//! onto meshes with each noise combo (no calibration/mapping — this is the
//! motivation figure showing why IC+PM are needed).
//!
//! Fig. 1(c): runtime of noise-free matrix multiplication vs. noise-modeled
//! simulation (the paper's motivation for *in-situ* rather than simulated
//! training).

use l2ight::data::{DatasetKind, SynthSpec};
use l2ight::linalg::{matmul, Mat};
use l2ight::nn::{build_model, EngineKind, ModelArch, ProjEngine};
use l2ight::photonics::{NoiseModel, PtcMesh};
use l2ight::stages::pm::copy_aux_params;
use l2ight::stages::sl::{train, OptKind, SlConfig};
use l2ight::util::bench::{black_box, Bencher, Table};
use l2ight::util::Rng;

fn noise_combo(q: bool, ct: bool, dv: bool, pb: bool) -> NoiseModel {
    NoiseModel {
        phase_bits: if q { Some(8) } else { None },
        sigma_bits: if q { Some(16) } else { None },
        crosstalk: if ct { 0.005 } else { 0.0 },
        gamma_std: if dv { 0.002 } else { 0.0 },
        phase_bias: pb,
    }
}

/// Program the digital model's weights onto photonic meshes (ideal SVD
/// programming, exactly what naive deployment would do) and evaluate.
fn deploy_and_eval(
    digital: &mut l2ight::nn::Model,
    noise: NoiseModel,
    classes: usize,
    width: f32,
    test: &l2ight::data::Dataset,
    seed: u64,
) -> f32 {
    let mut rng = Rng::new(seed);
    let kind = EngineKind::Photonic { k: 9, noise };
    let mut chip = build_model(ModelArch::CnnS, kind, classes, width, &mut rng);
    // Naive deployment: per-engine program_from_dense (no IC/PM).
    let mut weights: Vec<Mat> = Vec::new();
    digital.for_each_layer(|l| {
        if let Some(e) = l.engine_mut() {
            weights.push(e.dense_weight());
        }
    });
    let mut wi = 0;
    chip.for_each_layer(|l| {
        if let Some(e) = l.engine_mut() {
            if let ProjEngine::Photonic { mesh, .. } = e {
                mesh.program_from_dense(&weights[wi]);
            }
            wi += 1;
        }
    });
    copy_aux_params(&mut chip, digital);
    test.evaluate(&mut chip, 32)
}

fn main() {
    println!("== Fig. 1(b): accuracy under non-ideality combos (naive deployment, CNN-S) ==");
    let width = 1.0f32;
    let (train_set, test_set) = SynthSpec::new(DatasetKind::MnistLike, 512, 256).generate();
    let mut rng = Rng::new(1);
    let mut digital = build_model(ModelArch::CnnS, EngineKind::Digital, 10, width, &mut rng);
    let cfg = SlConfig {
        epochs: 8,
        batch: 32,
        opt: OptKind::Sgd { lr: 0.05, momentum: 0.9, weight_decay: 1e-4 },
        eval_every: 0,
        ..SlConfig::default()
    };
    let pre = train(&mut digital, &train_set, &test_set, &cfg);
    println!("digital (noise-free) accuracy: {:.3}", pre.final_test_acc);

    let combos: &[(&str, NoiseModel)] = &[
        ("ideal", noise_combo(false, false, false, false)),
        ("Q", noise_combo(true, false, false, false)),
        ("Q+CT", noise_combo(true, true, false, false)),
        ("Q+CT+DV", noise_combo(true, true, true, false)),
        ("Q+CT+DV+PB", noise_combo(true, true, true, true)),
    ];
    let mut t = Table::new(&["noise", "acc (mean of 3 chips)", "acc drop vs digital"]);
    for (name, nm) in combos {
        let mut accs = Vec::new();
        for seed in 0..3u64 {
            accs.push(deploy_and_eval(&mut digital, *nm, 10, width, &test_set, 100 + seed) as f64);
        }
        let mean = accs.iter().sum::<f64>() / accs.len() as f64;
        t.row(&[
            name.to_string(),
            format!("{mean:.3}"),
            format!("{:+.3}", mean - pre.final_test_acc as f64),
        ]);
    }
    t.print("Fig 1(b) — accuracy vs noise combination");
    println!("(paper shape: accuracy degrades as CT/DV stack on Q; PB alone is fatal)");

    println!("\n== Fig. 1(c): noise-free matmul vs noise-simulated matmul runtime ==");
    let mut bench = Bencher::new(300, 15);
    let mut t2 = Table::new(&["size", "noise-free (dense)", "noise-sim (mesh)", "slowdown"]);
    for &n in &[36usize, 72, 144] {
        let mut rng = Rng::new(9);
        let a = Mat::randn(n, n, 1.0, &mut rng);
        let x = Mat::randn(n, 64, 1.0, &mut rng);
        let dense_ns = bench.bench(&format!("dense {n}"), || {
            black_box(matmul(&a, &x));
        });
        let mut mesh = PtcMesh::new(n, n, 9, NoiseModel::PAPER, &mut rng);
        mesh.program_from_dense(&a);
        let mesh_ns = bench.bench(&format!("mesh {n}"), || {
            mesh.invalidate(); // force noise re-realization: the Fig 1(c) cost
            black_box(mesh.forward(&x));
        });
        t2.row(&[
            format!("{n}x{n}"),
            l2ight::util::bench::fmt_ns(dense_ns),
            l2ight::util::bench::fmt_ns(mesh_ns),
            format!("{:.0}x", mesh_ns / dense_ns),
        ]);
    }
    t2.print("Fig 1(c) — noise simulation overhead");
    println!("(paper shape: noise-modeled simulation is far slower than the plain matmul,\n motivating in-situ learning instead of simulated training)");
}
