//! Fig. 11 + Table 2: accuracy and hardware-efficiency comparison of the
//! sampling strategies on VGG-8 and ResNet-18 (width-scaled, synthetic
//! CIFAR-10 at side 16 — DESIGN.md §4 substitutions; the compared
//! quantities are *ratios and orderings*, which are shape- not
//! capacity-dependent).
//!
//! Rows (paper Table 2):
//!   L2ight-SL (Baseline)          — subspace learning from scratch, dense
//!   + Feedback Sampling (α_W)     — btopk + exp
//!   + Column Sampling (α_C)       — CS added
//!   + Data Sampling (α_D)         — SMD added
//!   + RAD [36]                    — spatial sampling baseline
//!   + SWAT-U [38]                 — sparse weight+activation baseline
//!   L2ight (IC→PM→SL)             — the full flow with pretrained weights

use l2ight::baselines;
use l2ight::coordinator::{JobConfig, MetricSink, Protocol};
use l2ight::data::DatasetKind;
use l2ight::nn::{build_model, EngineKind, ModelArch};
use l2ight::photonics::NoiseModel;
use l2ight::profiler::{print_cost_table, CostBreakdown};
use l2ight::stages::sl::{train, SlConfig};
use l2ight::util::Rng;

struct Row {
    label: String,
    acc: f32,
    act_red: f32,
    cost: CostBreakdown,
    steps_total: f64,
}

fn scratch_run(
    arch: ModelArch,
    sl_cfg: &SlConfig,
    label: &str,
    swat_alpha_w: Option<f32>,
    datasets: &(l2ight::data::Dataset, l2ight::data::Dataset),
) -> Row {
    let mut rng = Rng::new(0xbead);
    let kind = EngineKind::Photonic { k: 9, noise: NoiseModel::quant_only(8) };
    let mut model = build_model(arch, kind, 10, WIDTH, &mut rng);
    if let Some(aw) = swat_alpha_w {
        baselines::apply_swat_forward_masks(&mut model, aw);
    }
    let r = train(&mut model, &datasets.0, &datasets.1, sl_cfg);
    let acc = if swat_alpha_w.is_some() {
        baselines::clear_forward_masks(&mut model);
        datasets.1.evaluate(&mut model, sl_cfg.batch)
    } else {
        r.best_test_acc
    };
    Row {
        label: label.to_string(),
        acc,
        act_red: sl_cfg.feature.act_reduction(),
        cost: r.cost,
        steps_total: r.cost.total_steps(),
    }
}

const WIDTH: f32 = 0.25;

fn bench_model(arch: ModelArch) {
    println!("\n==== {} (width {WIDTH}, synthetic CIFAR-10 @16x16) ====", arch.name());
    let spec = l2ight::data::SynthSpec::new(DatasetKind::Cifar10Like, 256, 128).with_side(16);
    let datasets = spec.generate();
    let base = SlConfig {
        epochs: 6,
        batch: 32,
        eval_every: 0,
        seed: 0x7ab2,
        ..SlConfig::default()
    };
    // Paper Table-2 sparsities (VGG-8 row set).
    let (aw, ac, ad) = (0.6f32, 0.6f32, 0.5f32);

    let mut rows: Vec<Row> = Vec::new();
    rows.push(scratch_run(arch, &base, "L2ight-SL (BS)", None, &datasets));
    rows.push(scratch_run(
        arch,
        &baselines::l2ight_sl_config(aw, 1.0, 0.0, &base),
        &format!("+FS (aW={aw})"),
        None,
        &datasets,
    ));
    rows.push(scratch_run(
        arch,
        &baselines::l2ight_sl_config(aw, ac, 0.0, &base),
        &format!("+CS (aC={ac})"),
        None,
        &datasets,
    ));
    rows.push(scratch_run(
        arch,
        &baselines::l2ight_sl_config(aw, ac, ad, &base),
        &format!("+DS (aD={ad})"),
        None,
        &datasets,
    ));
    rows.push(scratch_run(
        arch,
        &baselines::rad_config(0.85, &base), // α_S = keep 0.85 (Act↓ ≈ 15%)
        "RAD (aS=0.85)",
        None,
        &datasets,
    ));
    rows.push(scratch_run(
        arch,
        &baselines::swat_config(0.3, 0.6, &base),
        "SWAT-U (aW=0.3,aS=0.6)",
        Some(0.3),
        &datasets,
    ));

    // Full flow through the driver (pretrain → IC → PM → sparse SL).
    let cfg = JobConfig {
        arch,
        dataset: DatasetKind::Cifar10Like,
        protocol: Protocol::L2ight,
        k: 9,
        noise: NoiseModel::quant_only(8),
        width: WIDTH,
        n_train: 256,
        n_test: 128,
        pretrain_epochs: 4,
        epochs: 1,
        batch: 32,
        alpha_w: aw,
        alpha_c: ac,
        alpha_d: ad,
        zo_budget: 0.05,
        seed: 0x7ab2,
        robustness: None,
        sharding: None,
        variation: None,
    };
    // Same 16x16 side for the driver-built datasets: rebuild by hand.
    let mut sink = MetricSink::memory();
    let s = {
        // run_job builds full-side datasets; emulate with the same flow at
        // side 16 by training directly: pretrain → map → SL.
        use l2ight::stages::pm::{copy_aux_params, map_model, PmConfig};
        use l2ight::stages::sl::OptKind;
        use l2ight::zoo::ZoConfig;
        let mut rng = Rng::new(cfg.seed);
        let mut digital = build_model(arch, EngineKind::Digital, 10, WIDTH, &mut rng);
        let pre_cfg = SlConfig {
            epochs: cfg.pretrain_epochs,
            opt: OptKind::Sgd { lr: 0.05, momentum: 0.9, weight_decay: 1e-4 },
            ..base.clone()
        };
        let pre = train(&mut digital, &datasets.0, &datasets.1, &pre_cfg);
        let kind = EngineKind::Photonic { k: 9, noise: cfg.noise };
        let mut chip = build_model(arch, kind, 10, WIDTH, &mut rng);
        let pm_cfg = PmConfig {
            zo: ZoConfig { iters: 6, ..PmConfig::default().zo },
            alternations: 1,
            ..PmConfig::default()
        };
        map_model(&mut chip, &mut digital, &pm_cfg);
        copy_aux_params(&mut chip, &mut digital);
        chip.reset_mesh_stats();
        let sl_cfg = baselines::l2ight_sl_config(
            aw,
            ac,
            ad,
            &SlConfig { epochs: 1, opt: OptKind::AdamW { lr: 2e-4, weight_decay: 1e-2 }, ..base.clone() },
        );
        let r = train(&mut chip, &datasets.0, &datasets.1, &sl_cfg);
        let _ = (&mut sink, pre);
        Row {
            label: "L2ight (IC->PM->SL)".into(),
            acc: r.best_test_acc,
            act_red: sl_cfg.feature.act_reduction(),
            cost: r.cost,
            steps_total: r.cost.total_steps(),
        }
    };
    let _ = cfg;
    rows.push(s);

    // Print the Table-2 layout.
    let mut acc_table = l2ight::util::bench::Table::new(&["config", "acc", "Act down (%)"]);
    for r in &rows {
        acc_table.row(&[
            r.label.clone(),
            format!("{:.3}", r.acc),
            format!("{:.1}", r.act_red * 100.0),
        ]);
    }
    acc_table.print(&format!("Table 2 ({}) — accuracy", arch.name()));
    let cost_rows: Vec<(String, CostBreakdown)> =
        rows.iter().map(|r| (r.label.clone(), r.cost)).collect();
    print_cost_table(
        &format!("Table 2 ({}) — PTC energy & steps (unit 1e6; ratio vs BS)", arch.name()),
        &cost_rows,
        1e6,
    );
    // Shape check: the full flow trains 1 epoch on a mapped model — its
    // energy/steps must be far below BS (the 30x claim's mechanism).
    let bs = rows[0].cost.total_energy();
    let full = rows.last().unwrap().cost.total_energy();
    println!(
        "\nfull-flow energy ratio vs BS: {:.1}x (paper: 32-36x; driven by fewer epochs after mapping + sparsity)",
        bs / full.max(1.0)
    );
    let _ = rows.iter().map(|r| r.steps_total).sum::<f64>();
}

fn main() {
    println!("== Fig. 11 / Table 2: sampling-strategy efficiency comparison ==");
    bench_model(ModelArch::Vgg8);
    bench_model(ModelArch::ResNet18);
    println!("\n(paper shape: FS+CS+DS ≈ 3.2-3.6x cheaper than BS with ~2% acc cost;");
    println!(" RAD saves nothing on PTC energy; SWAT-U loses accuracy to forward sparsity;");
    println!(" the full flow is ~30x+ cheaper because mapping leaves SL only light work)");
}
