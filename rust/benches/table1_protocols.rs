//! Table 1: scalability comparison with prior ONN on-chip training
//! protocols — the static comparison grid plus a measured query-cost probe
//! that shows *why* the #Params columns are what they are: ZO query count
//! per update scales with the phase-space dimension, first-order subspace
//! cost does not.

use l2ight::baselines::{flops_train, mixedtrn_train, ZoTrainConfig};
use l2ight::data::{DatasetKind, SynthSpec};
use l2ight::nn::{build_model, EngineKind, ModelArch};
use l2ight::photonics::NoiseModel;
use l2ight::stages::sl::{train, SlConfig};
use l2ight::util::bench::Table;
use l2ight::util::{fmt_sig, Rng};

fn main() {
    // The grid of Table 1 (documented characteristics of each protocol).
    let mut t = Table::new(&["", "BFT[41]", "PSO[56]", "AVM[24]", "FLOPS[20]", "MixedTrn[17]", "L2ight"]);
    t.row(&["#Params".into(), "~100".into(), "~100".into(), "~100".into(), "~1000".into(), "~2500".into(), "~10M".into()]);
    t.row(&["Algorithm".into(), "ZO".into(), "ZO".into(), "FO".into(), "ZO".into(), "ZO".into(), "ZO+FO".into()]);
    t.row(&["Resolution req.".into(), "Medium".into(), "High".into(), "Medium".into(), "High".into(), "Med".into(), "Medium".into()]);
    t.row(&["Observability".into(), "Coh. I/O".into(), "Coh. I/O".into(), "Coh. I/O + per-device".into(), "Coh. I/O".into(), "Coh. I/O".into(), "Coh. I/O".into()]);
    t.print("Table 1 — protocol comparison grid (paper values)");

    println!("\n== measured: hardware queries per effective update vs phase dimension ==");
    let (train_set, test_set) =
        SynthSpec::new(DatasetKind::VowelLike, 128, 64).with_difficulty(0.5).generate();
    let mut t2 = Table::new(&[
        "width",
        "#phases",
        "FLOPS queries/iter",
        "MixedTrn queries/iter",
        "L2ight PTC-calls/iter",
    ]);
    for width in [0.5f32, 1.0, 2.0] {
        let kind = EngineKind::Photonic { k: 4, noise: NoiseModel::PAPER };
        let mut m_flops = build_model(ModelArch::MlpVowel, kind, 4, width, &mut Rng::new(1));
        let mut m_mixed = m_flops.clone();
        let mut m_ours = m_flops.clone();
        let phases: usize = {
            let mut n = 0;
            m_flops.for_each_layer(|l| {
                if let Some(l2ight::nn::ProjEngine::Photonic { mesh, .. }) = l.engine_mut() {
                    n += mesh.ptcs.iter().map(|p| p.n_phases()).sum::<usize>();
                }
            });
            n
        };
        let iters = train_set.n.div_ceil(32);
        let zo_cfg = ZoTrainConfig { epochs: 1, batch: 32, grad_samples: 5, ..Default::default() };
        let rf = flops_train(&mut m_flops, &train_set, &test_set, &zo_cfg);
        let rm = mixedtrn_train(&mut m_mixed, &train_set, &test_set, &zo_cfg);
        m_ours.reset_mesh_stats();
        let rs = train(&mut m_ours, &train_set, &test_set, &SlConfig::quick(1, 32));
        t2.row(&[
            format!("{width:.1}"),
            phases.to_string(),
            fmt_sig(rf.queries as f64 / iters as f64, 3),
            fmt_sig(rm.queries as f64 / iters as f64, 3),
            fmt_sig(rs.cost.total_energy() / iters as f64, 3),
        ]);
    }
    t2.print("Table 1 (measured) — per-iteration hardware cost scaling");
    println!("\n(paper shape: MixedTrn's query count grows with the phase count — the");
    println!(" scalability wall; L2ight's first-order cost grows only with the model's");
    println!(" forward cost, independent of the number of *trainable* phases)");
}
