//! Fig. 4(b): zeroth-order optimizers on identity calibration — loss (the
//! |·|-identity surrogate MSE) vs iteration for ZGD / ZCD / ZTP, each with
//! and without best-solution recording ("-B" variants).
//!
//! Paper shape to reproduce: coordinate-wise methods (ZCD, ZTP) converge
//! faster and lower than gradient-estimate ZGD; best-recording stabilizes
//! all of them.

use l2ight::photonics::ptc::Ptc;
use l2ight::photonics::NoiseModel;
use l2ight::stages::ic::calibrate_ptc;
use l2ight::stages::ic::IcConfig;
use l2ight::util::bench::Table;
use l2ight::util::{fmt_sig, Rng};
use l2ight::zoo::{ZoConfig, ZoKind};

fn run(kind: ZoKind, best: bool, iters: usize, seeds: u64) -> Vec<f64> {
    let mut mean_trace = vec![0.0f64; iters];
    // Per-optimizer step tuning (the paper tunes each method's lr): ZTP
    // moves along a *normalized* direction, so its effective per-coordinate
    // step is step/sqrt(dim) and needs a larger base step.
    let step = match kind {
        ZoKind::Ztp => 1.2,
        _ => 0.15,
    };
    for seed in 0..seeds {
        let mut rng = Rng::new(1000 + seed);
        let mut ptc = Ptc::new(9, NoiseModel::PAPER, &mut rng);
        let cfg = IcConfig {
            optimizer: kind,
            zo: ZoConfig {
                iters,
                step,
                decay: 0.995,
                step_floor: 2e-3,
                best_recording: best,
            },
            ..IcConfig::default()
        };
        let mut zo_rng = Rng::with_stream(7, seed);
        let (report, _) = calibrate_ptc(&mut ptc, &cfg, &mut zo_rng);
        for (m, &v) in mean_trace.iter_mut().zip(&report.trace) {
            *m += v / seeds as f64;
        }
    }
    mean_trace
}

fn main() {
    println!("== Fig. 4(b): ZO optimizers on identity calibration (9x9 PTC, paper noise) ==");
    let iters = 400;
    let seeds = 3;
    let variants: &[(&str, ZoKind, bool)] = &[
        ("ZGD", ZoKind::Zgd, false),
        ("ZGD-B", ZoKind::Zgd, true),
        ("ZCD", ZoKind::Zcd, false),
        ("ZCD-B", ZoKind::Zcd, true),
        ("ZTP", ZoKind::Ztp, false),
        ("ZTP-B", ZoKind::Ztp, true),
    ];
    let checkpoints = [9usize, 49, 99, 199, 399];
    let mut t = Table::new(&["optimizer", "it=10", "it=50", "it=100", "it=200", "it=400"]);
    let mut finals: Vec<(String, f64)> = Vec::new();
    for (name, kind, best) in variants {
        let trace = run(*kind, *best, iters, seeds);
        let mut cells = vec![name.to_string()];
        for &c in &checkpoints {
            cells.push(fmt_sig(trace[c], 3));
        }
        finals.push((name.to_string(), trace[iters - 1]));
        t.row(&cells);
    }
    t.print("Fig 4(b) — surrogate loss (MSE^U + MSE^V) vs iteration, mean of 3 chips");

    // Shape assertions (reported, not fatal): coordinate methods beat ZGD.
    let get = |n: &str| finals.iter().find(|(a, _)| a == n).unwrap().1;
    let verdict = |ok: bool| if ok { "OK (matches paper)" } else { "MISMATCH" };
    println!(
        "\nZCD-B < ZGD-B final loss: {}  ({} vs {})",
        verdict(get("ZCD-B") < get("ZGD-B")),
        fmt_sig(get("ZCD-B"), 3),
        fmt_sig(get("ZGD-B"), 3)
    );
    println!(
        "ZTP-B < ZGD-B final loss: {}  ({} vs {})",
        verdict(get("ZTP-B") < get("ZGD-B")),
        fmt_sig(get("ZTP-B"), 3),
        fmt_sig(get("ZGD-B"), 3)
    );
    println!(
        "best-recording helps ZGD: {}  ({} vs {})",
        verdict(get("ZGD-B") <= get("ZGD") + 1e-9),
        fmt_sig(get("ZGD-B"), 3),
        fmt_sig(get("ZGD"), 3)
    );
}
